//! Baseline algorithms the paper positions itself against.
//!
//! * [`CentralizedSgd`] — all data in one pool, one variable (the §V-E
//!   "centralized version of SGD" whose accuracy Alg. 2 matches).
//! * [`sync_dsgd`] — synchronous decentralized subgradient descent
//!   (Nedić–Ozdaglar [14]): every slot, all nodes step + average with
//!   neighbors. Needs slot synchronization — the thing the paper avoids.
//! * [`server_worker`] — the Fig. 1(a) parameter-server strawman with a
//!   drop-the-stragglers policy ("the late workers are simply ignored").
//! * [`local_only`] — no communication at all: the lower bound showing
//!   why per-node data skew demands consensus.
//!
//! All run on rust-native math; the straggler comparison in
//! [`crate::sim`] wraps them with a virtual clock.

mod centralized;
mod local_only;
mod server_worker;
mod sync_dsgd;

pub use centralized::CentralizedSgd;
pub use local_only::{local_only_errors, local_only_errors_for, local_only_errors_plan};
pub use server_worker::{server_worker, server_worker_plan, ServerWorkerConfig, ServerWorkerReport};
pub use sync_dsgd::{sync_dsgd, sync_dsgd_plan, SyncDsgdConfig, SyncDsgdReport};
