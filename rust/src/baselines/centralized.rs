//! Centralized SGD: the single-machine reference (§V-E compares Alg. 2's
//! final error to "a centralized version of SGD").

use crate::coordinator::StepSize;
use crate::data::Dataset;
use crate::metrics::{Record, Recorder};
use crate::model::LogReg;
use crate::util::rng::Xoshiro256pp;
use crate::util::Stopwatch;

/// Plain single-variable SGD over the pooled data.
pub struct CentralizedSgd {
    pub model: LogReg,
    pub stepsize: StepSize,
    pub rng: Xoshiro256pp,
    pub k: u64,
}

impl CentralizedSgd {
    pub fn new(dim: usize, classes: usize, stepsize: StepSize, seed: u64) -> Self {
        Self {
            model: LogReg::zeros(dim, classes),
            stepsize,
            rng: Xoshiro256pp::seeded(seed),
            k: 0,
        }
    }

    /// Run `iters` single-sample SGD steps over the pooled dataset,
    /// evaluating every `eval_every`.
    pub fn run(
        &mut self,
        pool: &Dataset,
        test: &Dataset,
        iters: u64,
        eval_every: u64,
    ) -> Recorder {
        assert!(!pool.is_empty());
        let mut rec = Recorder::new("centralized");
        let sw = Stopwatch::new();
        let test_flat = test.features_flat();
        let test_labels = test.labels();
        let snap = |k: u64, model: &LogReg, grad_steps: u64, sw: &Stopwatch, rec: &mut Recorder| {
            let e = model.evaluate(test_flat, test_labels);
            rec.push(Record {
                k,
                time_secs: sw.elapsed_secs(),
                consensus: 0.0, // single variable: always at consensus
                test_loss: e.mean_loss() as f64,
                test_err: e.error_rate() as f64,
                grad_steps,
                ..Default::default()
            });
        };
        snap(self.k, &self.model, self.k, &sw, &mut rec);
        let mut next = eval_every;
        for _ in 0..iters {
            let idx = self.rng.index(pool.len());
            let s = pool.sample(idx);
            let lr = self.stepsize.at(self.k);
            self.model.sgd_step(&[s.features], &[s.label], lr, 1.0);
            self.k += 1;
            if self.k >= next {
                snap(self.k, &self.model, self.k, &sw, &mut rec);
                next += eval_every;
            }
        }
        snap(self.k, &self.model, self.k, &sw, &mut rec);
        rec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;

    #[test]
    fn centralized_learns_pooled_mixture() {
        let gen = SyntheticGen::new(4, 10, 4, 2.5, 0.4, 0.3, 3);
        let mut rng = Xoshiro256pp::seeded(1);
        let mut pool = Dataset::new(10, 4);
        for i in 0..4 {
            pool.extend(&gen.node_dataset(i, 100, &mut rng));
        }
        let test = gen.global_test_set(300, &mut rng);
        let mut sgd = CentralizedSgd::new(
            10,
            4,
            StepSize::Poly {
                a: 1.0,
                tau: 500.0,
                pow: 0.75,
            },
            7,
        );
        let rec = sgd.run(&pool, &test, 3000, 1000);
        let first = rec.records.first().unwrap().test_err;
        let last = rec.last().unwrap().test_err;
        assert!(last < first, "err {first} -> {last}");
        assert!(last < 0.4, "final err {last}");
    }
}
