//! Centralized SGD: the single-machine reference (§V-E compares Alg. 2's
//! final error to "a centralized version of SGD"). Objective-generic:
//! the same loop optimizes any §II loss family.

use crate::coordinator::StepSize;
use crate::data::Dataset;
use crate::metrics::Recorder;
use crate::node_logic::{self, Counts, Probe, Strategy};
use crate::objective::Objective;
use crate::util::rng::Xoshiro256pp;
use crate::util::Stopwatch;

/// Plain single-variable SGD over the pooled data.
pub struct CentralizedSgd {
    pub objective: Objective,
    dim: usize,
    classes: usize,
    /// The single global parameter vector.
    pub w: Vec<f32>,
    pub stepsize: StepSize,
    pub rng: Xoshiro256pp,
    pub k: u64,
}

impl CentralizedSgd {
    /// Logistic-regression reference (the paper's §V-E baseline).
    pub fn new(dim: usize, classes: usize, stepsize: StepSize, seed: u64) -> Self {
        Self::for_objective(Objective::LogReg, dim, classes, stepsize, seed)
    }

    /// The centralized reference for a [`WorkloadPlan`]: one variable,
    /// all shards pooled into a single dataset (returned alongside).
    /// Requires a single loss family — one pooled variable cannot
    /// optimize two objectives at once, so mixed plans have no
    /// centralized counterpart.
    pub fn from_plan(
        plan: &crate::workload::WorkloadPlan,
        stepsize: StepSize,
        seed: u64,
    ) -> (Self, Dataset) {
        assert!(
            !plan.is_mixed(),
            "a mixed-objective plan has no single centralized reference"
        );
        let mut pool = Dataset::new(plan.dim(), plan.classes());
        for i in 0..plan.len() {
            pool.extend(plan.shard(i));
        }
        let sgd = Self::for_objective(
            plan.objective(0),
            plan.dim(),
            plan.classes(),
            stepsize,
            seed,
        );
        (sgd, pool)
    }

    /// Centralized SGD on an arbitrary §II objective.
    pub fn for_objective(
        objective: Objective,
        dim: usize,
        classes: usize,
        stepsize: StepSize,
        seed: u64,
    ) -> Self {
        Self {
            w: vec![0.0; objective.param_len(dim, classes)],
            objective,
            dim,
            classes,
            stepsize,
            rng: Xoshiro256pp::seeded(seed),
            k: 0,
        }
    }

    /// Run `iters` single-sample SGD steps over the pooled dataset,
    /// evaluating every `eval_every`.
    pub fn run(
        &mut self,
        pool: &Dataset,
        test: &Dataset,
        iters: u64,
        eval_every: u64,
    ) -> Recorder {
        assert!(!pool.is_empty());
        // Classic references always run the canonical Eq. (6) rule —
        // the baseline strategy is their single entry point to it.
        let mut strategy = node_logic::StrategyKind::Dasgd.build(0.0);
        let mut rec = Recorder::new("centralized");
        let sw = Stopwatch::new();
        let probe = Probe::new(self.objective, test);
        let snap = |k: u64, w: &[f32], sw: &Stopwatch, rec: &mut Recorder| {
            let counts = Counts {
                grad_steps: k,
                ..Counts::default()
            };
            // Single variable: always at consensus (distance 0).
            rec.push(probe.snapshot_at(k, sw.elapsed_secs(), w, 0.0, &counts));
        };
        snap(self.k, &self.w, &sw, &mut rec);
        let mut next = eval_every;
        for _ in 0..iters {
            let lr = self.stepsize.at(self.k);
            let mut w = std::mem::take(&mut self.w);
            strategy.step_sample(
                self.objective,
                &mut w,
                pool,
                &mut self.rng,
                self.dim,
                self.classes,
                lr,
                1.0,
            );
            self.w = w;
            self.k += 1;
            if self.k >= next {
                snap(self.k, &self.w, &sw, &mut rec);
                next += eval_every;
            }
        }
        snap(self.k, &self.w, &sw, &mut rec);
        rec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;

    fn pooled_world(n: usize, seed: u64) -> (Dataset, Dataset) {
        let gen = SyntheticGen::new(n, 10, 4, 2.5, 0.4, 0.3, seed);
        let mut rng = Xoshiro256pp::seeded(seed ^ 1);
        let mut pool = Dataset::new(10, 4);
        for i in 0..n {
            pool.extend(&gen.node_dataset(i, 100, &mut rng));
        }
        (pool, gen.global_test_set(300, &mut rng))
    }

    #[test]
    fn centralized_learns_pooled_mixture() {
        let (pool, test) = pooled_world(4, 3);
        let mut sgd = CentralizedSgd::new(
            10,
            4,
            StepSize::Poly {
                a: 1.0,
                tau: 500.0,
                pow: 0.75,
            },
            7,
        );
        let rec = sgd.run(&pool, &test, 3000, 1000);
        let first = rec.records.first().unwrap().test_err;
        let last = rec.last().unwrap().test_err;
        assert!(last < first, "err {first} -> {last}");
        assert!(last < 0.4, "final err {last}");
    }

    #[test]
    fn from_plan_pools_every_shard() {
        use crate::workload::PlanSpec;
        let (plan, test) =
            PlanSpec::Dirichlet { alpha: 0.3 }.build(Objective::LogReg, 4, 50, 100, 11);
        let (mut sgd, pool) = CentralizedSgd::from_plan(&plan, StepSize::paper_default(1), 3);
        assert_eq!(pool.len(), 4 * 50);
        assert_eq!(sgd.w.len(), 50 * 10);
        let rec = sgd.run(&pool, &test, 500, 500);
        assert!(rec.last().unwrap().test_err.is_finite());
    }

    #[test]
    #[should_panic(expected = "no single centralized reference")]
    fn from_plan_rejects_mixed_objectives() {
        use crate::workload::PlanSpec;
        let (plan, _) = PlanSpec::Mixed { alpha: 0.5 }.build(Objective::LogReg, 4, 30, 10, 1);
        let _ = CentralizedSgd::from_plan(&plan, StepSize::paper_default(1), 3);
    }

    #[test]
    fn centralized_hinge_and_lasso_improve() {
        let (pool, test) = pooled_world(4, 9);
        for obj in [Objective::hinge(), Objective::lasso()] {
            let mut sgd =
                CentralizedSgd::for_objective(obj, 10, 4, obj.default_stepsize(1), 5);
            let rec = sgd.run(&pool, &test, 4000, 4000);
            let first = rec.records.first().unwrap().test_err;
            let last = rec.last().unwrap().test_err;
            assert!(last < first, "{obj}: metric {first} -> {last}");
            assert_eq!(sgd.w.len(), 10, "{obj} parameter shape");
        }
    }
}
