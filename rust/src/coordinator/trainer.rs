//! The Alg. 2 trainer: sequential-event implementation with the paper's
//! exact iteration semantics (one update per k — a gradient step on the
//! selected node with probability p_grad, otherwise the Eq. (7)
//! projection onto the selected node's closed neighborhood).
//!
//! Selection can be central (the paper's analysis model) or the §IV-A
//! distributed geometric countdown, in which case simultaneous firings
//! are resolved by the §IV-C conflict policy. A truly concurrent,
//! thread-per-node implementation lives in
//! [`async_runtime`](super::async_runtime); this sequential one is the
//! reference for the figures because its iteration counter k matches the
//! paper's plots exactly.

use anyhow::Result;

use crate::data::Dataset;
use crate::graph::Graph;
use crate::metrics::{Record, Recorder};
use crate::util::rng::Xoshiro256pp;
use crate::util::Stopwatch;

use super::backend::{EvalBatch, StepBackend};
use super::config::{ConflictPolicy, SelectionMode, TrainConfig};
use super::consensus;
use super::node::NodeState;
use super::selector::{CentralSelector, GeometricSelector, Slot};

/// Cumulative counters of a run.
#[derive(Clone, Copy, Debug, Default)]
pub struct Counters {
    pub grad_steps: u64,
    pub proj_steps: u64,
    /// Data-plane messages in the canonical [`crate::node_logic`]
    /// convention: 2·|N_m| (collect + broadcast) per applied
    /// projection; lock-up control traffic is not counted.
    pub messages: u64,
    /// Simultaneous-firing events whose closed neighborhoods intersected.
    pub conflicts: u64,
    /// Updates aborted by the lock-up protocol.
    pub aborted: u64,
}

/// The networked-system trainer.
pub struct Trainer<B: StepBackend> {
    pub cfg: TrainConfig,
    pub graph: Graph,
    pub nodes: Vec<NodeState>,
    backend: B,
    central: Option<CentralSelector>,
    distributed: Option<GeometricSelector>,
    rng: Xoshiro256pp,
    pub counters: Counters,
    /// Paper iteration counter: applied updates.
    pub k: u64,
}

impl<B: StepBackend> Trainer<B> {
    /// Build a trainer: one node per graph vertex, each holding `shards[i]`.
    /// The backend's [`Objective`](crate::objective::Objective) decides
    /// the per-node parameter shape and step/eval semantics.
    pub fn new(cfg: TrainConfig, graph: Graph, shards: Vec<Dataset>, backend: B) -> Self {
        assert_eq!(graph.len(), shards.len(), "one shard per node");
        assert!(graph.is_connected(), "consensus needs a connected graph");
        let dim = shards[0].dim();
        let classes = shards[0].classes();
        let param_len = backend.objective().param_len(dim, classes);
        let mut root = Xoshiro256pp::seeded(cfg.seed);
        let nodes: Vec<NodeState> = shards
            .into_iter()
            .enumerate()
            .map(|(i, d)| {
                let mut node = NodeState::new(i, param_len, d, root.split(i as u64));
                if cfg.init_scale > 0.0 {
                    for v in &mut node.w {
                        *v = node.rng.gauss_f32(0.0, cfg.init_scale);
                    }
                }
                node
            })
            .collect();
        let n = nodes.len();
        let (central, distributed) = match cfg.selection {
            SelectionMode::Central => (Some(CentralSelector::uniform(n)), None),
            SelectionMode::DistributedGeometric { p } => (
                None,
                Some(GeometricSelector::uniform(n, p, cfg.seed ^ 0xD15C0)),
            ),
        };
        Self {
            rng: root.split(u64::MAX),
            cfg,
            graph,
            nodes,
            backend,
            central,
            distributed,
            counters: Counters::default(),
            k: 0,
        }
    }

    pub fn backend(&self) -> &B {
        &self.backend
    }

    /// Current parameter vectors (β_1, …, β_N).
    pub fn params(&self) -> Vec<Vec<f32>> {
        self.nodes.iter().map(|n| n.w.clone()).collect()
    }

    /// d^k for the current state.
    pub fn consensus_distance(&self) -> f64 {
        consensus::consensus_distance(&self.params())
    }

    /// One gradient step on node `m` (Eq. 6): only β_m changes.
    fn grad_update(&mut self, m: usize) -> Result<()> {
        let lr = self.cfg.stepsize.at(self.k);
        let scale = 1.0 / self.nodes.len() as f32;
        let batch = self.cfg.batch;
        let (xs, labels) = self.nodes[m].draw_batch(batch);
        let mut w = std::mem::take(&mut self.nodes[m].w);
        self.backend.grad_step(&mut w, &xs, &labels, lr, scale)?;
        self.nodes[m].w = w;
        self.nodes[m].grad_steps += 1;
        self.counters.grad_steps += 1;
        Ok(())
    }

    /// One projection step on node `m` (Eq. 7): the closed neighborhood
    /// {m} ∪ N_m moves to its average. Costs 2·|N_m| messages
    /// (collect + broadcast).
    fn proj_update(&mut self, m: usize) -> Result<()> {
        let hood = self.graph.closed_neighborhood(m);
        let rows: Vec<&[f32]> = hood.iter().map(|&i| self.nodes[i].w.as_slice()).collect();
        let avg = self.backend.gossip_avg(&rows)?;
        for &i in &hood {
            self.nodes[i].w.copy_from_slice(&avg);
        }
        self.nodes[m].proj_steps += 1;
        self.counters.proj_steps += 1;
        self.counters.messages += crate::node_logic::projection_messages(hood.len());
        Ok(())
    }

    /// Apply Alg. 2's action for node `m`: gradient step w.p. p_grad,
    /// projection otherwise. Increments k (an applied update).
    fn act(&mut self, m: usize) -> Result<()> {
        let r = self.rng.next_f64();
        if r < self.cfg.p_grad {
            self.grad_update(m)?;
        } else {
            self.proj_update(m)?;
        }
        self.k += 1;
        Ok(())
    }

    /// Resolve one selection slot into applied updates, honoring the
    /// §IV-C conflict policy for simultaneous firings.
    fn process_slot(&mut self, slot: Slot) -> Result<()> {
        if slot.fired.len() == 1 {
            return self.act(slot.fired[0]);
        }
        // Simultaneous firings: count pairwise conflicts.
        let mut fired = slot.fired;
        self.rng.shuffle(&mut fired);
        let mut locked: Vec<usize> = Vec::new();
        for &m in &fired {
            let conflicts_with_locked = locked
                .iter()
                .any(|&l| self.graph.closed_neighborhoods_intersect(m, l));
            if conflicts_with_locked {
                self.counters.conflicts += 1;
                match self.cfg.conflicts {
                    ConflictPolicy::LockUp => {
                        // m backed off; lock-up control traffic is not
                        // data-plane and is not counted as messages
                        // (the canonical `node_logic` convention).
                        self.counters.aborted += 1;
                        continue;
                    }
                    ConflictPolicy::Ignore => {
                        // Applied anyway (the "noisy" alternative).
                    }
                }
            }
            locked.push(m);
            self.act(m)?;
        }
        Ok(())
    }

    /// Run until `k ≥ iters`, evaluating β̄ every `eval_every` applied
    /// updates (k = 0 included). Returns the recorded series.
    pub fn run(
        &mut self,
        iters: u64,
        eval_every: u64,
        test: &Dataset,
        name: &str,
    ) -> Result<Recorder> {
        let test_batch = self.backend.eval_batch(test);
        let mut rec = Recorder::new(name);
        let sw = Stopwatch::new();
        self.record(&mut rec, &test_batch, &sw)?;
        let mut next_eval = eval_every;
        while self.k < iters {
            let slot = match (&mut self.central, &mut self.distributed) {
                (Some(c), _) => c.next(&mut self.rng),
                (_, Some(d)) => d.next(),
                _ => unreachable!(),
            };
            self.process_slot(slot)?;
            if self.k >= next_eval {
                self.record(&mut rec, &test_batch, &sw)?;
                next_eval += eval_every;
            }
        }
        self.record(&mut rec, &test_batch, &sw)?;
        Ok(rec)
    }

    fn record(&mut self, rec: &mut Recorder, test: &EvalBatch, sw: &Stopwatch) -> Result<()> {
        let params = self.params();
        let mean = consensus::mean_param(&params);
        let (loss, err) = self.backend.evaluate(&mean, test)?;
        rec.push(Record {
            k: self.k,
            time_secs: sw.elapsed_secs(),
            consensus: consensus::consensus_distance(&params),
            test_loss: loss as f64,
            test_err: err as f64,
            grad_steps: self.counters.grad_steps,
            proj_steps: self.counters.proj_steps,
            messages: self.counters.messages,
            conflicts: self.counters.conflicts,
            staleness_p50: 0.0,
            staleness_p99: 0.0,
            staging_bytes: 0,
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::backend::NativeBackend;
    use crate::data::SyntheticGen;
    use crate::graph::regular_circulant;

    fn small_setup(
        n: usize,
        k: usize,
        seed: u64,
    ) -> (Graph, Vec<Dataset>, Dataset, NativeBackend) {
        let gen = SyntheticGen::new(n, 10, 4, 2.0, 0.5, 0.3, seed);
        let mut rng = Xoshiro256pp::seeded(seed ^ 1);
        let shards = (0..n).map(|i| gen.node_dataset(i, 80, &mut rng)).collect();
        let test = gen.global_test_set(200, &mut rng);
        (
            regular_circulant(n, k),
            shards,
            test,
            NativeBackend::new(10, 4),
        )
    }

    #[test]
    fn alg2_reaches_consensus_and_learns() {
        let (g, shards, test, backend) = small_setup(8, 4, 3);
        let cfg = TrainConfig::paper_default(8).with_seed(5);
        let mut t = Trainer::new(cfg, g, shards, backend);
        let rec = t.run(6000, 1000, &test, "test").unwrap();
        let first = &rec.records[0];
        let last = rec.last().unwrap();
        // Consensus distance shrinks by a lot.
        assert!(
            last.consensus < first.consensus.max(1.0) * 0.5 || last.consensus < 1.0,
            "consensus {} -> {}",
            first.consensus,
            last.consensus
        );
        // Better than random guessing (0.75 for 4 classes).
        assert!(last.test_err < 0.5, "err={}", last.test_err);
        // Both step kinds happened, roughly half/half.
        let total = t.counters.grad_steps + t.counters.proj_steps;
        assert_eq!(total, t.k);
        let frac = t.counters.grad_steps as f64 / total as f64;
        assert!((frac - 0.5).abs() < 0.1, "grad fraction {frac}");
        // Projections exchanged messages.
        assert!(t.counters.messages > 0);
    }

    #[test]
    fn p_grad_one_never_projects() {
        let (g, shards, test, backend) = small_setup(6, 2, 7);
        let cfg = TrainConfig::paper_default(6).with_p_grad(1.0).with_seed(1);
        let mut t = Trainer::new(cfg, g, shards, backend);
        t.run(500, 250, &test, "t").unwrap();
        assert_eq!(t.counters.proj_steps, 0);
        assert_eq!(t.counters.grad_steps, 500);
        assert_eq!(t.counters.messages, 0);
    }

    #[test]
    fn p_grad_zero_is_pure_consensus() {
        let (g, shards, test, backend) = small_setup(6, 2, 9);
        let cfg = TrainConfig::paper_default(6).with_p_grad(0.0).with_seed(2);
        let mut t = Trainer::new(cfg, g, shards, backend);
        // Seed the nodes with distinct params, then gossip only.
        for (i, node) in t.nodes.iter_mut().enumerate() {
            node.w.iter_mut().for_each(|v| *v = i as f32);
        }
        let d0 = t.consensus_distance();
        t.run(400, 200, &test, "t").unwrap();
        assert_eq!(t.counters.grad_steps, 0);
        let d1 = t.consensus_distance();
        assert!(d1 < d0 * 1e-3, "consensus {d0} -> {d1}");
    }

    #[test]
    fn distributed_selection_matches_central_statistics() {
        let (g, shards, test, backend) = small_setup(8, 4, 11);
        let cfg = TrainConfig {
            selection: SelectionMode::DistributedGeometric { p: 0.1 },
            ..TrainConfig::paper_default(8)
        }
        .with_seed(3);
        let mut t = Trainer::new(cfg, g, shards, backend);
        let rec = t.run(4000, 2000, &test, "t").unwrap();
        // Conflicts occurred (p is high enough for ties on 8 nodes)...
        assert!(t.counters.conflicts > 0, "expected ties at p=0.1");
        // ...and training still works.
        assert!(rec.last().unwrap().test_err < 0.55);
        // Every node got selected.
        assert!(t.nodes.iter().all(|n| n.grad_steps + n.proj_steps > 0));
    }

    #[test]
    fn lockup_aborts_ignore_does_not() {
        let mk = |policy| {
            let (g, shards, test, backend) = small_setup(8, 4, 13);
            let cfg = TrainConfig {
                selection: SelectionMode::DistributedGeometric { p: 0.25 },
                conflicts: policy,
                ..TrainConfig::paper_default(8)
            }
            .with_seed(4);
            let mut t = Trainer::new(cfg, g, shards, backend);
            t.run(2000, 2000, &test, "t").unwrap();
            t.counters
        };
        let lock = mk(ConflictPolicy::LockUp);
        let ignore = mk(ConflictPolicy::Ignore);
        assert!(lock.aborted > 0);
        assert_eq!(ignore.aborted, 0);
        assert!(ignore.conflicts > 0);
    }

    #[test]
    #[should_panic(expected = "connected")]
    fn disconnected_graph_rejected() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        let gen = SyntheticGen::new(4, 10, 4, 2.0, 0.5, 0.3, 1);
        let mut rng = Xoshiro256pp::seeded(2);
        let shards = (0..4).map(|i| gen.node_dataset(i, 10, &mut rng)).collect();
        Trainer::new(
            TrainConfig::paper_default(4),
            g,
            shards,
            NativeBackend::new(10, 4),
        );
    }
}
