//! Compute backends for the coordinator: rust-native math or the
//! AOT-compiled JAX/Pallas artifacts through PJRT.
//!
//! Both implement [`StepBackend`] with identical semantics (the
//! integration suite asserts they agree to float tolerance), so every
//! experiment can run on either and the figures are backend-independent.
//!
//! Since the objective redesign, neither backend hardwires a loss:
//! [`StepBackend::grad_step`] and [`StepBackend::evaluate`] dispatch on
//! the backend's [`Objective`] (logreg / hinge-SVM / lasso), the
//! objective owns the parameter shape and label encoding, and
//! [`PjrtArtifacts::for_objective`] maps each objective to its compiled
//! kernel set — step, eval, and gossip artifacts exist for all three
//! families (hinge/lasso in their (1, 50) synthetic shape), so the PJRT
//! backend runs every piece on compiled kernels. The native fallback
//! remains only for shapes no artifact covers (e.g. a gossip stack
//! wider than the compiled padding).

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::objective::Objective;
use crate::runtime::Engine;

/// A held-out evaluation batch in the layouts both backends need.
#[derive(Clone, Debug)]
pub struct EvalBatch {
    pub n: usize,
    pub dim: usize,
    pub classes: usize,
    pub features: Vec<f32>,
    pub one_hot: Vec<f32>,
    pub labels: Vec<usize>,
    /// Per-sample scalar targets in the objective's encoding (empty for
    /// batches built without an objective; logreg never reads them).
    pub targets: Vec<f32>,
}

impl EvalBatch {
    /// Build the flat buffers for rows `0..n` of `d`, indexing
    /// cyclically, in one pass (no intermediate `Dataset` copy). The
    /// one-hot matrix is only materialized when asked for — it exists
    /// solely for the logreg PJRT eval artifact.
    fn build(d: &Dataset, n: usize, with_one_hot: bool) -> Self {
        assert!(!d.is_empty());
        let (dim, classes) = (d.dim(), d.classes());
        let mut features = Vec::with_capacity(n * dim);
        let mut one_hot = if with_one_hot {
            vec![0.0f32; n * classes]
        } else {
            Vec::new()
        };
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let s = d.sample(i % d.len());
            features.extend_from_slice(s.features);
            if with_one_hot {
                one_hot[i * classes + s.label] = 1.0;
            }
            labels.push(s.label);
        }
        Self {
            n,
            dim,
            classes,
            features,
            one_hot,
            labels,
            targets: Vec::new(),
        }
    }

    pub fn from_dataset(d: &Dataset) -> Self {
        Self::build(d, d.len(), true)
    }

    /// Resize cyclically to exactly `n` rows (the PJRT eval artifact has
    /// a fixed 256-row shape).
    pub fn from_dataset_resized(d: &Dataset, n: usize) -> Self {
        Self::build(d, n, true)
    }

    /// Batch with targets encoded for `obj`, optionally resized to the
    /// backend's required row count.
    pub fn for_objective(obj: Objective, d: &Dataset, rows: Option<usize>) -> Self {
        let mut b = Self::build(
            d,
            rows.unwrap_or_else(|| d.len()),
            matches!(obj, Objective::LogReg),
        );
        b.targets = obj.encode_targets(&b.labels, b.classes);
        b
    }

    /// Evaluate `w` on this batch with `obj`'s native math: returns
    /// `(loss, err)` — the shared metric path for monitors and
    /// baselines (the batch already knows its own shape).
    pub fn eval(&self, obj: Objective, w: &[f32]) -> (f32, f32) {
        obj.native_eval(
            w,
            self.dim,
            self.classes,
            &self.features,
            &self.labels,
            &self.targets,
        )
    }
}

/// The compute interface the trainer drives.
pub trait StepBackend {
    /// The loss family this backend computes.
    fn objective(&self) -> Objective;

    /// One SGD/subgradient step of the backend's objective on flat
    /// row-major data: `w ← w − lr·scale·∇`; returns the minibatch mean
    /// loss. `labels` are dataset class labels — the objective applies
    /// its own encoding (one-hot / ±1 / centered regression target).
    fn grad_step(
        &mut self,
        w: &mut Vec<f32>,
        xs: &[f32],
        labels: &[usize],
        lr: f32,
        scale: f32,
    ) -> Result<f32>;

    /// Weighted average of the stacked parameter rows (Eq. 7 projection).
    fn gossip_avg(&mut self, rows: &[&[f32]]) -> Result<Vec<f32>>;

    /// (mean loss, error metric) of `w` on the eval batch. The error
    /// column is objective-defined: misclassification rate for
    /// logreg/hinge, RMSE for lasso.
    fn evaluate(&mut self, w: &[f32], test: &EvalBatch) -> Result<(f32, f32)>;

    /// Rows the eval batch must have (PJRT artifacts are fixed-shape).
    fn required_eval_rows(&self) -> Option<usize> {
        None
    }

    /// Build the eval batch this backend needs for `test`.
    fn eval_batch(&self, test: &Dataset) -> EvalBatch {
        EvalBatch::for_objective(self.objective(), test, self.required_eval_rows())
    }

    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Pure-rust backend (crate::model math), generic over the objective.
pub struct NativeBackend {
    dim: usize,
    classes: usize,
    objective: Objective,
}

impl NativeBackend {
    /// Logistic-regression backend (the paper's default).
    pub fn new(dim: usize, classes: usize) -> Self {
        Self::for_objective(Objective::LogReg, dim, classes)
    }

    /// Backend for an arbitrary §II objective.
    pub fn for_objective(objective: Objective, dim: usize, classes: usize) -> Self {
        Self {
            dim,
            classes,
            objective,
        }
    }
}

impl StepBackend for NativeBackend {
    fn objective(&self) -> Objective {
        self.objective
    }

    fn grad_step(
        &mut self,
        w: &mut Vec<f32>,
        xs: &[f32],
        labels: &[usize],
        lr: f32,
        scale: f32,
    ) -> Result<f32> {
        Ok(self
            .objective
            .native_step(w, xs, labels, self.dim, self.classes, lr, scale))
    }

    fn gossip_avg(&mut self, rows: &[&[f32]]) -> Result<Vec<f32>> {
        Ok(crate::linalg::mean_of(rows))
    }

    fn evaluate(&mut self, w: &[f32], test: &EvalBatch) -> Result<(f32, f32)> {
        Ok(test.eval(self.objective, w))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Artifact names for one (objective, shape-family) pair.
///
/// `eval` / `gossip` are `Option` so a future family without a compiled
/// artifact of that kind degrades to native math with identical
/// semantics; all three current objectives compile both.
#[derive(Clone, Debug)]
pub struct PjrtArtifacts {
    pub objective: Objective,
    pub step_b1: String,
    /// Batch-8 step artifact: one mean-gradient step over 8 feature
    /// rows. The executor scheduler uses it to collapse a backlogged
    /// node's owed gradient firings into a single compiled call
    /// (`None` degrades to repeated `step_b1`).
    pub step_b8: Option<String>,
    pub eval: Option<String>,
    pub gossip: Option<String>,
    /// Max rows of the gossip artifact's stacked-parameter input.
    pub gossip_m: usize,
    /// Fixed row count of the eval artifact.
    pub eval_rows: Option<usize>,
}

/// Rows per batched step call — the batch size the `_b8` artifacts are
/// compiled for (`python/compile/aot.py`).
pub const STEP_BATCH: usize = 8;

impl PjrtArtifacts {
    /// Artifact set for `obj` in shape family `family` (`"synth"` = 50
    /// features, `"notmnist"` = 256; hinge/lasso exist for synth only).
    pub fn for_objective(obj: Objective, family: &str) -> Self {
        let eval = obj.pjrt_eval_artifact(family);
        Self {
            eval_rows: eval.as_ref().map(|_| 256),
            step_b1: obj.pjrt_step_artifact(family),
            step_b8: Some(obj.pjrt_step_artifact_b8(family)),
            gossip: obj.pjrt_gossip_artifact(family),
            gossip_m: 16,
            eval,
            objective: obj,
        }
    }

    /// The logreg synthetic (50×10) artifact family.
    pub fn synth() -> Self {
        Self::for_objective(Objective::LogReg, "synth")
    }

    /// The logreg notMNIST (256×10) artifact family.
    pub fn notmnist() -> Self {
        Self::for_objective(Objective::LogReg, "notmnist")
    }

    /// Artifact names that must exist in the engine manifest.
    pub fn required(&self) -> Vec<&str> {
        let mut names = vec![self.step_b1.as_str()];
        names.extend(self.step_b8.as_deref());
        names.extend(self.eval.as_deref());
        names.extend(self.gossip.as_deref());
        names
    }

    /// Stage `rows` for the gossip artifact: the zero-padded
    /// `(gossip_m, k)` parameter stack plus uniform averaging weights.
    /// `None` when the neighborhood exceeds the compiled padding (the
    /// caller averages natively). The single staging implementation for
    /// both the sequential backend and the threaded executor path.
    pub fn stage_gossip(&self, rows: &[&[f32]], k: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        let m = self.gossip_m;
        if rows.len() > m {
            return None;
        }
        let mut p = vec![0.0f32; m * k];
        let mut wts = vec![0.0f32; m];
        for (r, row) in rows.iter().enumerate() {
            debug_assert_eq!(row.len(), k);
            p[r * k..(r + 1) * k].copy_from_slice(row);
            wts[r] = 1.0 / rows.len() as f32;
        }
        Some((p, wts))
    }
}

/// PJRT backend: the production path (Pallas kernels inside AOT HLO).
pub struct PjrtBackend {
    engine: Engine,
    arts: PjrtArtifacts,
    dim: usize,
    classes: usize,
}

impl PjrtBackend {
    pub fn new(engine: Engine, arts: PjrtArtifacts, dim: usize, classes: usize) -> Result<Self> {
        // The hinge/lasso step kernels are compiled for the (1, 50)
        // synthetic shape only — fail up front rather than deep inside
        // input staging on the first step.
        if arts.objective != Objective::LogReg && dim != 50 {
            bail!(
                "{} PJRT kernels are compiled for the 50-feature synth family only \
                 (got dim {dim}); use the native backend for this shape",
                arts.objective.name()
            );
        }
        for name in arts.required() {
            if !engine.has(name) {
                bail!("engine is missing artifact {name}");
            }
        }
        Ok(Self {
            engine,
            arts,
            dim,
            classes,
        })
    }

    /// Synthetic-shape logreg backend from the default artifact dir.
    pub fn synth_default() -> Result<Self> {
        Self::new(Engine::load_default()?, PjrtArtifacts::synth(), 50, 10)
    }

    /// notMNIST-shape logreg backend from the default artifact dir.
    pub fn notmnist_default() -> Result<Self> {
        Self::new(Engine::load_default()?, PjrtArtifacts::notmnist(), 256, 10)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl StepBackend for PjrtBackend {
    fn objective(&self) -> Objective {
        self.arts.objective
    }

    fn grad_step(
        &mut self,
        w: &mut Vec<f32>,
        xs: &[f32],
        labels: &[usize],
        lr: f32,
        scale: f32,
    ) -> Result<f32> {
        if labels.len() != 1 {
            bail!("pjrt backend: only batch=1 steps are wired (got {})", labels.len());
        }
        assert_eq!(xs.len(), self.dim);
        let staged = self
            .arts
            .objective
            .step_inputs(labels[0], self.classes, lr, scale);
        let outs = self
            .engine
            .execute_f32(&self.arts.step_b1, &staged.buffers(w, xs))?;
        let mut it = outs.into_iter();
        *w = it.next().unwrap();
        Ok(it.next().unwrap()[0])
    }

    fn gossip_avg(&mut self, rows: &[&[f32]]) -> Result<Vec<f32>> {
        let Some(gossip) = self.arts.gossip.as_deref() else {
            // No compiled gossip for this objective's parameter shape.
            return Ok(crate::linalg::mean_of(rows));
        };
        let k = self.arts.objective.param_len(self.dim, self.classes);
        let Some((p, wts)) = self.arts.stage_gossip(rows, k) else {
            // Degree exceeds the artifact's padding: fall back to native.
            return Ok(crate::linalg::mean_of(rows));
        };
        let outs = self.engine.execute_f32(gossip, &[&p, &wts])?;
        Ok(outs.into_iter().next().unwrap())
    }

    fn evaluate(&mut self, w: &[f32], test: &EvalBatch) -> Result<(f32, f32)> {
        let Some(eval) = self.arts.eval.as_deref() else {
            // No compiled eval for this objective: native metrics.
            return Ok(test.eval(self.arts.objective, w));
        };
        let rows = self.arts.eval_rows.expect("eval artifact has fixed rows");
        if test.n != rows {
            bail!(
                "pjrt eval artifact needs exactly {rows} rows, got {} — use \
                 EvalBatch::from_dataset_resized",
                test.n
            );
        }
        // Input protocol per family: logreg takes one-hot labels;
        // hinge/lasso take the encoded scalar targets plus λ (staged at
        // call time, so artifacts stay λ-agnostic).
        let obj = self.arts.objective;
        let outs = match obj {
            Objective::LogReg => self
                .engine
                .execute_f32(eval, &[w, &test.features, &test.one_hot])?,
            Objective::Hinge { lam } | Objective::Lasso { lam } => {
                if test.targets.len() != test.n {
                    bail!(
                        "{} eval needs encoded targets — build the batch with \
                         EvalBatch::for_objective",
                        obj.name()
                    );
                }
                let lam = [lam];
                self.engine
                    .execute_f32(eval, &[w, &test.features, &test.targets, &lam])?
            }
        };
        Ok(obj.pjrt_eval_outputs(outs[0][0], outs[1][0], test.n))
    }

    fn required_eval_rows(&self) -> Option<usize> {
        self.arts.eval_rows
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn native_grad_step_reduces_loss() {
        let mut b = NativeBackend::new(8, 3);
        let mut rng = Xoshiro256pp::seeded(0);
        let mut w = vec![0.0f32; 24];
        let means: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..8).map(|_| rng.gauss_f32(0.0, 2.0)).collect())
            .collect();
        let mut first = 0.0;
        let mut last = 0.0;
        for k in 0..200 {
            let label = rng.index(3);
            let x: Vec<f32> = means[label].iter().map(|v| v + rng.gauss_f32(0.0, 0.2)).collect();
            let loss = b.grad_step(&mut w, &x, &[label], 0.5, 1.0).unwrap();
            if k < 20 {
                first += loss;
            } else if k >= 180 {
                last += loss;
            }
        }
        assert!(last < first * 0.6);
    }

    #[test]
    fn hinge_backend_learns_split() {
        // 2 classes → encoded ±1; a linear separator must emerge through
        // the same grad_step interface the trainer drives.
        let obj = Objective::hinge();
        let mut b = NativeBackend::for_objective(obj, 8, 2);
        let mut rng = Xoshiro256pp::seeded(4);
        let true_w: Vec<f32> = (0..8).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
        let mut w = vec![0.0f32; 8];
        let mut late_errs = 0;
        for step in 0..1500 {
            let x: Vec<f32> = (0..8).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let label = usize::from(crate::linalg::dot(&true_w, &x) <= 0.0);
            if step >= 1200 {
                let y = obj.encode_label(label, 2);
                if (crate::linalg::dot(&w, &x) > 0.0) != (y > 0.0) {
                    late_errs += 1;
                }
            }
            b.grad_step(&mut w, &x, &[label], 0.1, 1.0).unwrap();
        }
        assert!(late_errs < 40, "late errors {late_errs}/300");
        assert_eq!(w.len(), 8, "hinge parameter stays (dim)");
    }

    #[test]
    fn native_gossip_is_mean() {
        let mut b = NativeBackend::new(2, 1);
        let r1 = [1.0f32, 3.0];
        let r2 = [3.0f32, 5.0];
        let avg = b.gossip_avg(&[&r1, &r2]).unwrap();
        assert_eq!(avg, vec![2.0, 4.0]);
    }

    #[test]
    fn eval_batch_layouts() {
        let mut d = Dataset::new(2, 2);
        d.push(&[1.0, 0.0], 0);
        d.push(&[0.0, 1.0], 1);
        let e = EvalBatch::from_dataset(&d);
        assert_eq!(e.n, 2);
        assert_eq!(e.one_hot, vec![1.0, 0.0, 0.0, 1.0]);
        let r = EvalBatch::from_dataset_resized(&d, 5);
        assert_eq!(r.n, 5);
        assert_eq!(r.labels, vec![0, 1, 0, 1, 0]);
        // Direct flat construction matches the old two-pass layout.
        assert_eq!(r.features, vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        assert_eq!(
            r.one_hot,
            vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 1.0, 1.0, 0.0]
        );
    }

    #[test]
    fn eval_batch_objective_targets() {
        let mut d = Dataset::new(2, 2);
        d.push(&[1.0, 0.0], 0);
        d.push(&[0.0, 1.0], 1);
        let h = EvalBatch::for_objective(Objective::hinge(), &d, Some(3));
        assert_eq!(h.targets, vec![1.0, -1.0, 1.0]);
        let l = EvalBatch::for_objective(Objective::lasso(), &d, None);
        assert_eq!(l.targets, vec![-0.5, 0.5]);
        // Backends hand out a matching batch builder.
        let nb = NativeBackend::for_objective(Objective::hinge(), 2, 2);
        assert_eq!(nb.eval_batch(&d).targets, vec![1.0, -1.0]);
    }
}
