//! Compute backends for the coordinator: rust-native math or the
//! AOT-compiled JAX/Pallas artifacts through PJRT.
//!
//! Both implement [`StepBackend`] with identical semantics (the
//! integration suite asserts they agree to float tolerance), so every
//! experiment can run on either and the figures are backend-independent.

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::model::LogReg;
use crate::runtime::Engine;

/// A held-out evaluation batch in the layouts both backends need.
#[derive(Clone, Debug)]
pub struct EvalBatch {
    pub n: usize,
    pub dim: usize,
    pub classes: usize,
    pub features: Vec<f32>,
    pub one_hot: Vec<f32>,
    pub labels: Vec<usize>,
}

impl EvalBatch {
    pub fn from_dataset(d: &Dataset) -> Self {
        Self {
            n: d.len(),
            dim: d.dim(),
            classes: d.classes(),
            features: d.features_flat().to_vec(),
            one_hot: d.one_hot_labels(),
            labels: d.labels().to_vec(),
        }
    }

    /// Resize cyclically to exactly `n` rows (the PJRT eval artifact has
    /// a fixed 256-row shape).
    pub fn from_dataset_resized(d: &Dataset, n: usize) -> Self {
        Self::from_dataset(&d.resized_cyclic(n))
    }
}

/// The compute interface the trainer drives.
pub trait StepBackend {
    /// One logistic-regression SGD step on flat row-major data:
    /// `w ← w − lr·scale·∇`; returns the minibatch mean CE loss.
    fn grad_step(
        &mut self,
        w: &mut Vec<f32>,
        xs: &[f32],
        labels: &[usize],
        lr: f32,
        scale: f32,
    ) -> Result<f32>;

    /// Weighted average of the stacked parameter rows (Eq. 7 projection).
    fn gossip_avg(&mut self, rows: &[&[f32]]) -> Result<Vec<f32>>;

    /// (mean loss, error rate) of `w` on the eval batch.
    fn evaluate(&mut self, w: &[f32], test: &EvalBatch) -> Result<(f32, f32)>;

    /// Rows the eval batch must have (PJRT artifacts are fixed-shape).
    fn required_eval_rows(&self) -> Option<usize> {
        None
    }

    fn name(&self) -> &'static str;
}

// ---------------------------------------------------------------------------
// Native backend
// ---------------------------------------------------------------------------

/// Pure-rust backend (crate::model math).
pub struct NativeBackend {
    dim: usize,
    classes: usize,
}

impl NativeBackend {
    pub fn new(dim: usize, classes: usize) -> Self {
        Self { dim, classes }
    }
}

impl StepBackend for NativeBackend {
    fn grad_step(
        &mut self,
        w: &mut Vec<f32>,
        xs: &[f32],
        labels: &[usize],
        lr: f32,
        scale: f32,
    ) -> Result<f32> {
        let b = labels.len();
        assert_eq!(xs.len(), b * self.dim);
        let mut model = LogReg::from_weights(self.dim, self.classes, std::mem::take(w));
        let rows: Vec<&[f32]> = (0..b).map(|i| &xs[i * self.dim..(i + 1) * self.dim]).collect();
        let loss = model.sgd_step(&rows, labels, lr, scale);
        *w = model.w;
        Ok(loss)
    }

    fn gossip_avg(&mut self, rows: &[&[f32]]) -> Result<Vec<f32>> {
        Ok(crate::linalg::mean_of(rows))
    }

    fn evaluate(&mut self, w: &[f32], test: &EvalBatch) -> Result<(f32, f32)> {
        let model = LogReg::from_weights(self.dim, self.classes, w.to_vec());
        let eval = model.evaluate(&test.features, &test.labels);
        Ok((eval.mean_loss(), eval.error_rate()))
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Artifact names for one model shape.
#[derive(Clone, Debug)]
pub struct PjrtArtifacts {
    pub step_b1: String,
    pub eval: String,
    pub gossip: String,
    /// Max rows of the gossip artifact's stacked-parameter input.
    pub gossip_m: usize,
    /// Fixed row count of the eval artifact.
    pub eval_rows: usize,
}

impl PjrtArtifacts {
    /// The synthetic (50×10) artifact family.
    pub fn synth() -> Self {
        Self {
            step_b1: "logreg_step_synth_b1".into(),
            eval: "logreg_eval_synth".into(),
            gossip: "gossip_avg_synth".into(),
            gossip_m: 16,
            eval_rows: 256,
        }
    }

    /// The notMNIST (256×10) artifact family.
    pub fn notmnist() -> Self {
        Self {
            step_b1: "logreg_step_notmnist_b1".into(),
            eval: "logreg_eval_notmnist".into(),
            gossip: "gossip_avg_notmnist".into(),
            gossip_m: 16,
            eval_rows: 256,
        }
    }
}

/// PJRT backend: the production path (Pallas kernels inside AOT HLO).
pub struct PjrtBackend {
    engine: Engine,
    arts: PjrtArtifacts,
    dim: usize,
    classes: usize,
    /// Scratch for gossip stacking (avoids per-call allocation).
    gossip_scratch: Vec<f32>,
    weights_scratch: Vec<f32>,
}

impl PjrtBackend {
    pub fn new(engine: Engine, arts: PjrtArtifacts, dim: usize, classes: usize) -> Result<Self> {
        for name in [&arts.step_b1, &arts.eval, &arts.gossip] {
            if !engine.has(name) {
                bail!("engine is missing artifact {name}");
            }
        }
        let k = dim * classes;
        Ok(Self {
            engine,
            gossip_scratch: vec![0.0; 16 * k],
            weights_scratch: vec![0.0; 16],
            arts,
            dim,
            classes,
        })
    }

    /// Synthetic-shape backend from the default artifact dir.
    pub fn synth_default() -> Result<Self> {
        Self::new(Engine::load_default()?, PjrtArtifacts::synth(), 50, 10)
    }

    /// notMNIST-shape backend from the default artifact dir.
    pub fn notmnist_default() -> Result<Self> {
        Self::new(Engine::load_default()?, PjrtArtifacts::notmnist(), 256, 10)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl StepBackend for PjrtBackend {
    fn grad_step(
        &mut self,
        w: &mut Vec<f32>,
        xs: &[f32],
        labels: &[usize],
        lr: f32,
        scale: f32,
    ) -> Result<f32> {
        if labels.len() != 1 {
            bail!("pjrt backend: only batch=1 steps are wired (got {})", labels.len());
        }
        assert_eq!(xs.len(), self.dim);
        let mut y = vec![0.0f32; self.classes];
        y[labels[0]] = 1.0;
        let outs = self.engine.execute_f32(
            &self.arts.step_b1,
            &[w.as_slice(), xs, &y, &[lr], &[scale]],
        )?;
        let mut it = outs.into_iter();
        *w = it.next().unwrap();
        Ok(it.next().unwrap()[0])
    }

    fn gossip_avg(&mut self, rows: &[&[f32]]) -> Result<Vec<f32>> {
        let m = self.arts.gossip_m;
        if rows.len() > m {
            // Degree exceeds the artifact's padding: fall back to native.
            return Ok(crate::linalg::mean_of(rows));
        }
        let k = self.dim * self.classes;
        self.gossip_scratch.fill(0.0);
        self.weights_scratch.fill(0.0);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), k);
            self.gossip_scratch[i * k..(i + 1) * k].copy_from_slice(row);
            self.weights_scratch[i] = 1.0 / rows.len() as f32;
        }
        let outs = self.engine.execute_f32(
            &self.arts.gossip,
            &[&self.gossip_scratch, &self.weights_scratch],
        )?;
        Ok(outs.into_iter().next().unwrap())
    }

    fn evaluate(&mut self, w: &[f32], test: &EvalBatch) -> Result<(f32, f32)> {
        if test.n != self.arts.eval_rows {
            bail!(
                "pjrt eval artifact needs exactly {} rows, got {} — use \
                 EvalBatch::from_dataset_resized",
                self.arts.eval_rows,
                test.n
            );
        }
        let outs = self
            .engine
            .execute_f32(&self.arts.eval, &[w, &test.features, &test.one_hot])?;
        let loss_sum = outs[0][0];
        let errs = outs[1][0];
        Ok((loss_sum / test.n as f32, errs / test.n as f32))
    }

    fn required_eval_rows(&self) -> Option<usize> {
        Some(self.arts.eval_rows)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    #[test]
    fn native_grad_step_reduces_loss() {
        let mut b = NativeBackend::new(8, 3);
        let mut rng = Xoshiro256pp::seeded(0);
        let mut w = vec![0.0f32; 24];
        let means: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..8).map(|_| rng.gauss_f32(0.0, 2.0)).collect())
            .collect();
        let mut first = 0.0;
        let mut last = 0.0;
        for k in 0..200 {
            let label = rng.index(3);
            let x: Vec<f32> = means[label].iter().map(|v| v + rng.gauss_f32(0.0, 0.2)).collect();
            let loss = b.grad_step(&mut w, &x, &[label], 0.5, 1.0).unwrap();
            if k < 20 {
                first += loss;
            } else if k >= 180 {
                last += loss;
            }
        }
        assert!(last < first * 0.6);
    }

    #[test]
    fn native_gossip_is_mean() {
        let mut b = NativeBackend::new(2, 1);
        let r1 = [1.0f32, 3.0];
        let r2 = [3.0f32, 5.0];
        let avg = b.gossip_avg(&[&r1, &r2]).unwrap();
        assert_eq!(avg, vec![2.0, 4.0]);
    }

    #[test]
    fn eval_batch_layouts() {
        let mut d = Dataset::new(2, 2);
        d.push(&[1.0, 0.0], 0);
        d.push(&[0.0, 1.0], 1);
        let e = EvalBatch::from_dataset(&d);
        assert_eq!(e.n, 2);
        assert_eq!(e.one_hot, vec![1.0, 0.0, 0.0, 1.0]);
        let r = EvalBatch::from_dataset_resized(&d, 5);
        assert_eq!(r.n, 5);
        assert_eq!(r.labels, vec![0, 1, 0, 1, 0]);
    }
}
