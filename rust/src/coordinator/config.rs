//! Training configuration for the Alg. 2 coordinator.

use crate::objective::Objective;

/// Stepsize schedule α_k (the paper requires Σα = ∞, Σα² < ∞ for the
/// Theorem 1 guarantees; [`StepSize::Poly`] with pow ∈ (0.5, 1] satisfies
/// it).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepSize {
    /// Constant α (converges to a neighborhood only).
    Constant(f32),
    /// α_k = a / (1 + k/τ)^pow.
    Poly { a: f32, tau: f32, pow: f32 },
}

impl StepSize {
    pub fn at(&self, k: u64) -> f32 {
        match *self {
            StepSize::Constant(a) => a,
            StepSize::Poly { a, tau, pow } => a / (1.0 + k as f32 / tau).powf(pow),
        }
    }

    /// The paper-style default: effective unit step early, diminishing.
    pub fn paper_default(n_nodes: usize) -> Self {
        // The kernel applies lr·scale with scale = 1/N (Eq. 6), so fold N
        // into `a` to get an O(1) effective initial step.
        StepSize::Poly {
            a: 1.2 * n_nodes as f32,
            tau: 4000.0,
            pow: 0.75,
        }
    }
}

/// How the acting node is chosen each slot (§IV-A).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SelectionMode {
    /// Idealized central uniform selection (what the paper simulates).
    Central,
    /// Distributed geometric-countdown timers: every node draws
    /// Geometric(p) and counts down; ties = §IV-C conflicts.
    DistributedGeometric { p: f64 },
}

/// What to do when two adjacent nodes fire in the same slot (§IV-C).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ConflictPolicy {
    /// Neighbor lock-up: later node backs off (costs lock messages).
    LockUp,
    /// Ignore: both updates are applied (the paper's noisy alternative).
    Ignore,
}

/// Which layer executes the math.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Backend {
    /// Rust-native model math (baseline / cross-check).
    Native,
    /// AOT-compiled JAX/Pallas artifacts through PJRT (the real system).
    Pjrt,
}

/// Full coordinator configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Probability of a gradient step vs a projection step (paper: 0.5;
    /// §IV-B tunes it to trade communication for consensus speed).
    pub p_grad: f64,
    pub stepsize: StepSize,
    pub selection: SelectionMode,
    pub conflicts: ConflictPolicy,
    pub backend: Backend,
    /// The §II loss family the system optimizes. Used when constructing
    /// backends (e.g. [`crate::experiments::run_alg2`]); the trainer
    /// itself reads the objective off the backend it is given.
    pub objective: Objective,
    /// Microbatch per gradient step (paper: 1).
    pub batch: usize,
    /// Std-dev of the random initial β_i (0 = all-zeros init; > 0 gives
    /// the initial disagreement visible in the paper's Fig. 2).
    pub init_scale: f32,
    pub seed: u64,
}

impl TrainConfig {
    /// The paper's Alg. 2 configuration for an N-node system (logreg).
    pub fn paper_default(n_nodes: usize) -> Self {
        Self::objective_default(Objective::LogReg, n_nodes)
    }

    /// Alg. 2 configuration for an arbitrary objective: same selection /
    /// conflict policy, with the objective's stable stepsize schedule.
    pub fn objective_default(objective: Objective, n_nodes: usize) -> Self {
        Self {
            p_grad: 0.5,
            stepsize: objective.default_stepsize(n_nodes),
            selection: SelectionMode::Central,
            conflicts: ConflictPolicy::LockUp,
            backend: Backend::Native,
            objective,
            batch: 1,
            init_scale: 0.0,
            seed: 0,
        }
    }

    pub fn with_init_scale(mut self, s: f32) -> Self {
        self.init_scale = s;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_p_grad(mut self, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        self.p_grad = p;
        self
    }

    /// Swap the objective, keeping every other knob as configured.
    /// (Use [`TrainConfig::objective_default`] to also get the
    /// objective's stable stepsize.)
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poly_schedule_decreases() {
        let s = StepSize::Poly {
            a: 1.0,
            tau: 100.0,
            pow: 1.0,
        };
        assert!((s.at(0) - 1.0).abs() < 1e-6);
        assert!(s.at(100) < s.at(10));
        assert!((s.at(100) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn constant_schedule_constant() {
        let s = StepSize::Constant(0.3);
        assert_eq!(s.at(0), s.at(1_000_000));
    }

    #[test]
    fn paper_default_folds_n() {
        let s = StepSize::paper_default(30);
        // Effective initial step = a/N ≈ 1.2.
        assert!((s.at(0) / 30.0 - 1.2).abs() < 1e-5);
        let cfg = TrainConfig::paper_default(30);
        assert_eq!(cfg.p_grad, 0.5);
        assert_eq!(cfg.batch, 1);
        assert_eq!(cfg.objective, Objective::LogReg);
    }

    #[test]
    fn objective_default_uses_objective_stepsize() {
        let cfg = TrainConfig::objective_default(Objective::lasso(), 12);
        assert_eq!(cfg.objective, Objective::lasso());
        assert_eq!(cfg.stepsize, Objective::lasso().default_stepsize(12));
        // paper_default is exactly the logreg objective default.
        assert_eq!(
            TrainConfig::paper_default(12).stepsize,
            Objective::LogReg.default_stepsize(12)
        );
        let swapped = TrainConfig::paper_default(12).with_objective(Objective::hinge());
        assert_eq!(swapped.objective, Objective::hinge());
    }

    #[test]
    #[should_panic]
    fn p_grad_out_of_range_panics() {
        TrainConfig::paper_default(4).with_p_grad(1.5);
    }
}
