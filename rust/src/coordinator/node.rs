//! Per-node state: the local variable β_i, the local data shard, and a
//! private RNG stream (fully local randomness — no shared coordinator
//! state, as the paper's §IV-A requires).

use crate::data::Dataset;
use crate::util::rng::Xoshiro256pp;

/// One computing node of the networked system.
#[derive(Clone, Debug)]
pub struct NodeState {
    pub id: usize,
    /// Local variable β_i, flattened (dim × classes).
    pub w: Vec<f32>,
    /// Local shard — samples from this node's distribution V_i.
    pub data: Dataset,
    /// Private randomness (sample draws, countdown timers).
    pub rng: Xoshiro256pp,
    /// Gradient steps performed by this node.
    pub grad_steps: u64,
    /// Projection (gossip) steps initiated by this node.
    pub proj_steps: u64,
}

impl NodeState {
    pub fn new(id: usize, param_len: usize, data: Dataset, rng: Xoshiro256pp) -> Self {
        assert!(!data.is_empty(), "node {id} has no local data");
        Self {
            id,
            w: vec![0.0; param_len],
            data,
            rng,
            grad_steps: 0,
            proj_steps: 0,
        }
    }

    /// Sample a microbatch of local data uniformly with replacement —
    /// the "oracle to generate data sample" of Alg. 2. Returns flattened
    /// features and labels.
    pub fn draw_batch(&mut self, batch: usize) -> (Vec<f32>, Vec<usize>) {
        let dim = self.data.dim();
        let mut xs = Vec::with_capacity(batch * dim);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let idx = self.rng.index(self.data.len());
            let s = self.data.sample(idx);
            xs.extend_from_slice(s.features);
            labels.push(s.label);
        }
        (xs, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Dataset {
        let mut d = Dataset::new(3, 2);
        d.push(&[1.0, 2.0, 3.0], 0);
        d.push(&[4.0, 5.0, 6.0], 1);
        d
    }

    #[test]
    fn node_initializes_at_zero() {
        let n = NodeState::new(3, 6, dataset(), Xoshiro256pp::seeded(1));
        assert_eq!(n.w, vec![0.0; 6]);
        assert_eq!(n.id, 3);
    }

    #[test]
    fn draw_batch_shapes_and_coverage() {
        let mut n = NodeState::new(0, 6, dataset(), Xoshiro256pp::seeded(2));
        let (xs, labels) = n.draw_batch(4);
        assert_eq!(xs.len(), 12);
        assert_eq!(labels.len(), 4);
        // Over many draws both samples appear.
        let mut seen = [false; 2];
        for _ in 0..100 {
            let (_, l) = n.draw_batch(1);
            seen[l[0]] = true;
        }
        assert!(seen[0] && seen[1]);
    }

    #[test]
    #[should_panic(expected = "no local data")]
    fn empty_shard_rejected() {
        NodeState::new(0, 4, Dataset::new(2, 2), Xoshiro256pp::seeded(0));
    }
}
