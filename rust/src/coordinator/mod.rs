//! The paper's Layer-3 contribution: the fully distributed,
//! asynchronized SGD coordinator.
//!
//! * [`config`] — Alg. 2 hyperparameters + §IV policy knobs.
//! * [`backend`] — compute backends (rust-native vs PJRT artifacts),
//!   generic over the §II [`Objective`](crate::objective::Objective).
//! * [`selector`] — §IV-A node selection (central + distributed geometric).
//! * [`node`] — per-node state (β_i, local shard, private RNG).
//! * [`trainer`] — sequential-event Alg. 2 (the figures' reference).
//! * [`async_runtime`] — truly asynchronous runtime: a work-stealing
//!   executor pool (or the baseline thread-per-node engine) drives
//!   [`NodeLogic`](crate::node_logic::NodeLogic) tasks over a pluggable
//!   [`Transport`](crate::transport::Transport) (shared memory or
//!   message passing).
//! * [`consensus`] — d^k / DF(β) metrics.

pub mod async_runtime;
pub mod backend;
pub mod config;
pub mod consensus;
pub mod node;
pub mod selector;
pub mod trainer;

pub use async_runtime::{
    spawn_shard, spawn_shard_with_feeds, AsyncCluster, AsyncConfig, AsyncReport, EngineKind,
    ShardRun,
};
pub use backend::{EvalBatch, NativeBackend, PjrtArtifacts, PjrtBackend, StepBackend, STEP_BATCH};
pub use config::{Backend, ConflictPolicy, SelectionMode, StepSize, TrainConfig};
pub use crate::objective::Objective;
pub use node::NodeState;
pub use selector::{CentralSelector, GeometricSelector, Slot};
pub use trainer::{Counters, Trainer};
