//! Consensus metrics: the paper's d^k distance and DF(β) estimates.

use crate::graph::Graph;

/// d^k = Σ_i ‖β_i − β̄‖ — the §V-B "distance of the variables from
/// global consensus".
pub fn consensus_distance(params: &[Vec<f32>]) -> f64 {
    assert!(!params.is_empty());
    let n = params.len();
    let k = params[0].len();
    let mut mean = vec![0.0f64; k];
    for p in params {
        assert_eq!(p.len(), k);
        for (m, &v) in mean.iter_mut().zip(p) {
            *m += v as f64;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    params
        .iter()
        .map(|p| {
            p.iter()
                .zip(&mean)
                .map(|(&v, &m)| (v as f64 - m) * (v as f64 - m))
                .sum::<f64>()
                .sqrt()
        })
        .sum()
}

/// β̄ — the node-average parameter vector (the paper evaluates
/// prediction error at β̄, §V-C).
pub fn mean_param(params: &[Vec<f32>]) -> Vec<f32> {
    let rows: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    crate::linalg::mean_of(&rows)
}

/// Squared distance from the stacked variable to one constraint set
/// B_m = {β : β_m = β_j ∀ j ∈ N_m}: ‖β − Π_{B_m}(β)‖², i.e. the
/// within-closed-neighborhood variance times its size.
pub fn dist_to_constraint_sq(params: &[Vec<f32>], g: &Graph, m: usize) -> f64 {
    let hood = g.closed_neighborhood(m);
    let k = params[0].len();
    let mut mean = vec![0.0f64; k];
    for &i in &hood {
        for (acc, &v) in mean.iter_mut().zip(&params[i]) {
            *acc += v as f64;
        }
    }
    for v in &mut mean {
        *v /= hood.len() as f64;
    }
    hood.iter()
        .map(|&i| {
            params[i]
                .iter()
                .zip(&mean)
                .map(|(&v, &mu)| (v as f64 - mu) * (v as f64 - mu))
                .sum::<f64>()
        })
        .sum()
}

/// DF(β) estimate used in the Theorem-2 / Lemma-1 experiments: the exact
/// squared distance to the consensus set B = ∩B_i (for a connected graph
/// Π_B is the global mean), plus the max per-constraint distance that
/// appears in the linear-regularity condition.
#[derive(Clone, Copy, Debug)]
pub struct Feasibility {
    /// ‖β − Π_B(β)‖² — distance to full consensus.
    pub df_sq: f64,
    /// max_m ‖β − Π_{B_m}(β)‖² — the regularity right-hand side.
    pub max_constraint_sq: f64,
}

pub fn feasibility(params: &[Vec<f32>], g: &Graph) -> Feasibility {
    let n = params.len();
    let k = params[0].len();
    let mut mean = vec![0.0f64; k];
    for p in params {
        for (m, &v) in mean.iter_mut().zip(p) {
            *m += v as f64;
        }
    }
    for m in &mut mean {
        *m /= n as f64;
    }
    let df_sq = params
        .iter()
        .map(|p| {
            p.iter()
                .zip(&mean)
                .map(|(&v, &m)| (v as f64 - m) * (v as f64 - m))
                .sum::<f64>()
        })
        .sum();
    let max_constraint_sq = (0..n)
        .map(|m| dist_to_constraint_sq(params, g, m))
        .fold(0.0f64, f64::max);
    Feasibility {
        df_sq,
        max_constraint_sq,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ring;

    #[test]
    fn consensus_distance_zero_at_consensus() {
        let p = vec![vec![1.0f32, -2.0]; 5];
        assert!(consensus_distance(&p) < 1e-9);
    }

    #[test]
    fn consensus_distance_known_value() {
        // Two nodes at ±1 in 1-D: mean 0, each at distance 1 → d = 2.
        let p = vec![vec![1.0f32], vec![-1.0f32]];
        assert!((consensus_distance(&p) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn mean_param_is_elementwise_mean() {
        let p = vec![vec![1.0f32, 0.0], vec![3.0f32, 2.0]];
        assert_eq!(mean_param(&p), vec![2.0, 1.0]);
    }

    #[test]
    fn constraint_distance_zero_when_neighborhood_agrees() {
        let g = ring(4);
        // Nodes 0,1,3 (closed neighborhood of 0) equal; node 2 differs.
        let p = vec![
            vec![1.0f32],
            vec![1.0f32],
            vec![9.0f32],
            vec![1.0f32],
        ];
        assert!(dist_to_constraint_sq(&p, &g, 0) < 1e-12);
        assert!(dist_to_constraint_sq(&p, &g, 1) > 1.0);
    }

    #[test]
    fn feasibility_relations() {
        let g = ring(6);
        let p: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32]).collect();
        let f = feasibility(&p, &g);
        assert!(f.df_sq > 0.0);
        assert!(f.max_constraint_sq > 0.0);
        // Each constraint involves a subset ⇒ its distance ≤ DF.
        assert!(f.max_constraint_sq <= f.df_sq + 1e-9);
        // Linear regularity: η·DF ≤ max_constraint for some η ∈ (0,1).
        let eta = f.max_constraint_sq / f.df_sq;
        assert!(eta > 0.0 && eta <= 1.0);
    }
}
