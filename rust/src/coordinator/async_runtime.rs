//! Thread-per-node asynchronous runtime — the system the paper argues
//! for, with no global clock and no barriers.
//!
//! Every node runs on its own OS thread driving one
//! [`NodeLogic`](crate::node_logic::NodeLogic) (private RNG, exponential
//! inter-event clock — the continuous-time limit of §IV-A's geometric
//! countdown; per-node rates model heterogeneous hardware) over a
//! pluggable [`Transport`]:
//!
//! * [`TransportKind::SharedMem`] — sorted try-lock mutexes, the
//!   historical in-process substrate (behavior preserved bit-for-bit
//!   where seeds allow);
//! * [`TransportKind::Channel`] — message-passing collect/broadcast,
//!   the shape of a real deployment;
//! * [`TransportKind::Socket`] — the real deployment: constructed by
//!   `dasgd worker` / `dasgd launch` (see [`crate::net`]), where each
//!   process drives one shard of nodes via [`spawn_shard`] over a
//!   [`SocketNet`](crate::net::SocketNet).
//!
//! On firing, a node performs a gradient step (w.p. `p_grad`) on its
//! own variable, or a §IV-C lock-up + Eq. (7) projection over its
//! closed neighborhood. A busy neighborhood means *back off and redraw*
//! (a counted conflict), never a deadlock. Messages are counted in the
//! canonical [`crate::node_logic`] convention: `2·(h−1)` per applied
//! projection, nothing for aborts.
//!
//! Gradient/projection math runs rust-native by default or through the
//! channel-based [`ExecutorHandle`](crate::runtime::ExecutorHandle) (one
//! PJRT engine per executor thread) when an executor is supplied.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::data::stream::BlockBuffer;
use crate::data::Dataset;
use crate::graph::Graph;
use crate::metrics::Recorder;
use crate::node_logic::{
    neighborhood_average, projection_messages, Action, Counts, NodeLogic, Probe,
};
use crate::objective::Objective;
use crate::runtime::ExecutorHandle;
use crate::transport::{
    ChannelNet, ProjectionOutcome, SharedMem, Transport, TransportKind,
};
use crate::util::rng::Xoshiro256pp;
use crate::util::Stopwatch;
use crate::workload::WorkloadPlan;

use super::backend::PjrtArtifacts;
use super::config::StepSize;

/// Configuration of an asynchronous run.
#[derive(Clone, Debug)]
pub struct AsyncConfig {
    /// Gradient-step probability (paper: 0.5).
    pub p_grad: f64,
    pub stepsize: StepSize,
    /// Mean firing rate per node, events/second.
    pub rate_hz: f64,
    /// Heterogeneity: node i's rate is `rate_hz · exp(N(0, spread))` —
    /// spread 0 = homogeneous cluster, 1 ≈ mixed servers + phones.
    pub speed_spread: f64,
    /// Run length (wall-clock seconds).
    pub duration_secs: f64,
    /// Snapshot cadence for the monitor thread.
    pub eval_every_secs: f64,
    /// Simulated network hold time while a projection's locks are held
    /// (models the collect/broadcast RTT of a real deployment; 0 = the
    /// in-process memory-speed limit).
    pub gossip_hold_secs: f64,
    /// Fault injection: kill this many nodes after the given time — the
    /// paper's robustness motivation (no server = no single point of
    /// failure). Killed nodes stop updating and become unreachable to
    /// their neighbors' gossip; the survivors keep converging.
    pub kill_after_secs: Option<f64>,
    pub kill_nodes: usize,
    /// Which communication substrate the node threads run on.
    pub transport: TransportKind,
    pub seed: u64,
}

impl AsyncConfig {
    pub fn quick(n_nodes: usize) -> Self {
        Self {
            p_grad: 0.5,
            stepsize: StepSize::paper_default(n_nodes),
            rate_hz: 200.0,
            speed_spread: 0.0,
            duration_secs: 1.0,
            eval_every_secs: 0.25,
            gossip_hold_secs: 0.0,
            kill_after_secs: None,
            kill_nodes: 0,
            transport: TransportKind::SharedMem,
            seed: 0,
        }
    }
}

/// Outcome of an asynchronous run.
#[derive(Debug)]
pub struct AsyncReport {
    /// Nodes crashed by fault injection during the run.
    pub killed: usize,
    pub recorder: Recorder,
    pub updates: u64,
    pub grad_steps: u64,
    pub proj_steps: u64,
    /// Projection attempts aborted because the neighborhood was busy.
    pub conflicts: u64,
    pub messages: u64,
    pub updates_per_sec: f64,
    /// Final per-node parameters.
    pub final_params: Vec<Vec<f32>>,
}

/// Cross-thread run state: liveness, stop flag, and the shared counters
/// (parameters live in the [`Transport`]).
struct Shared {
    /// Per-node liveness: false = crashed (fault injection).
    alive: Vec<AtomicBool>,
    stop: AtomicBool,
    grad_steps: AtomicU64,
    proj_steps: AtomicU64,
    conflicts: AtomicU64,
    messages: AtomicU64,
    /// Applied-update counter across this process's node threads (for
    /// stepsize decay; in a multi-process deployment each worker decays
    /// on its local counter).
    k: AtomicU64,
}

impl Shared {
    fn new(n: usize) -> Self {
        Self {
            alive: (0..n).map(|_| AtomicBool::new(true)).collect(),
            stop: AtomicBool::new(false),
            grad_steps: AtomicU64::new(0),
            proj_steps: AtomicU64::new(0),
            conflicts: AtomicU64::new(0),
            messages: AtomicU64::new(0),
            k: AtomicU64::new(0),
        }
    }

    fn counts(&self) -> Counts {
        Counts {
            grad_steps: self.grad_steps.load(Ordering::Relaxed),
            proj_steps: self.proj_steps.load(Ordering::Relaxed),
            messages: self.messages.load(Ordering::Relaxed),
            conflicts: self.conflicts.load(Ordering::Relaxed),
        }
    }
}

/// A running set of node threads driving one *shard* of the system —
/// every node for the in-process engines, one worker's block for the
/// multi-process [`SocketNet`](crate::net::SocketNet) deployment.
/// Obtained from [`spawn_shard`]; stop with [`ShardRun::stop`] +
/// [`ShardRun::join`].
pub struct ShardRun {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ShardRun {
    /// Cumulative counters in the canonical convention.
    pub fn counts(&self) -> Counts {
        self.shared.counts()
    }

    /// Applied updates so far (this shard's stepsize clock).
    pub fn k(&self) -> u64 {
        self.shared.k.load(Ordering::Relaxed)
    }

    /// Fault injection: crash node `id` (it stops acting and becomes
    /// unreachable to its neighbors' gossip).
    pub fn kill(&self, id: usize) {
        self.shared.alive[id].store(false, Ordering::SeqCst);
    }

    pub fn alive(&self, id: usize) -> bool {
        self.shared.alive[id].load(Ordering::Relaxed)
    }

    /// Ask every node thread to stop after its current iteration.
    pub fn stop(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Wait for the node threads ([`ShardRun::stop`] first, or this
    /// blocks until something else stops them).
    pub fn join(self) {
        for h in self.handles {
            h.join().expect("node thread panicked");
        }
    }

    /// Stop, wait for every node thread, and return the final counters
    /// (read *after* the join, so no late increment is missed).
    pub fn stop_and_join(self) -> Counts {
        self.stop();
        let shared = Arc::clone(&self.shared);
        self.join();
        shared.counts()
    }
}

/// The RNG stream node `i` consumes. Derived from the run seed and the
/// node id alone — independent of spawn order — so every worker of a
/// sharded deployment reproduces exactly the per-node streams a
/// single-process run with the same seed would use.
fn node_rng(seed: u64, i: usize) -> Xoshiro256pp {
    Xoshiro256pp::seeded(seed).split(i as u64)
}

/// Spawn one thread per node in `owned`, each driving a [`NodeLogic`]
/// built from its [`WorkloadPlan`] assignment (objective + shard) over
/// `transport`. The engine-construction primitive behind
/// [`AsyncCluster::run`] (owned = all nodes) and the multi-process
/// worker (`dasgd worker`; owned = the worker's shard block).
///
/// Homogeneous plans use `cfg.stepsize` everywhere; mixed plans give
/// each node its own family's default schedule (one hinge-stable step
/// would overshoot the Lasso curvature bound — see
/// docs/heterogeneity.md).
pub fn spawn_shard(
    graph: &Graph,
    plan: &WorkloadPlan,
    cfg: &AsyncConfig,
    transport: Arc<dyn Transport>,
    owned: std::ops::Range<usize>,
    executor: Option<(ExecutorHandle, PjrtArtifacts)>,
) -> ShardRun {
    spawn_shard_with_feeds(graph, plan, cfg, transport, owned, executor, None)
}

/// [`spawn_shard`] for streamed plans: when `feeds` is given, each
/// owned node's [`NodeLogic`] starts with an *empty* shard fed by that
/// node's [`BlockBuffer`] receiver — the node steps as soon as its
/// first `ShardBlock` lands instead of waiting for the whole shard
/// (the plan's assignments then carry metadata only). `None` is the
/// historical fully-shipped path, bit-for-bit unchanged.
pub fn spawn_shard_with_feeds(
    graph: &Graph,
    plan: &WorkloadPlan,
    cfg: &AsyncConfig,
    transport: Arc<dyn Transport>,
    owned: std::ops::Range<usize>,
    executor: Option<(ExecutorHandle, PjrtArtifacts)>,
    feeds: Option<&Arc<BlockBuffer>>,
) -> ShardRun {
    let n = graph.len();
    assert_eq!(plan.len(), n, "one workload assignment per node");
    assert!(owned.end <= n);
    let (dim, classes) = (plan.dim(), plan.classes());
    let mixed = plan.is_mixed();
    let shared = Arc::new(Shared::new(n));
    let mut handles = Vec::with_capacity(owned.len());
    for i in owned {
        let mut rng = node_rng(cfg.seed, i);
        let rate = cfg.rate_hz * (rng.next_gauss() * cfg.speed_spread).exp();
        let a = plan.node(i);
        let logic = match feeds {
            Some(buffer) => NodeLogic::streaming(
                i,
                a.objective,
                cfg.p_grad,
                buffer.receiver(i),
                dim,
                classes,
                n,
                rng,
            ),
            None => NodeLogic::new(i, a.objective, cfg.p_grad, a.shard.clone(), n, rng),
        };
        let stepsize = if mixed {
            a.objective.default_stepsize(n)
        } else {
            cfg.stepsize
        };
        let shared = Arc::clone(&shared);
        let transport = Arc::clone(&transport);
        let graph = graph.clone();
        let cfg = cfg.clone();
        let executor = executor.as_ref().map(|(h, a)| (h.clone(), a.clone()));
        handles.push(std::thread::spawn(move || {
            node_loop(
                logic, rate, stepsize, shared, transport, graph, cfg, executor, dim, classes,
            );
        }));
    }
    ShardRun { shared, handles }
}

/// A networked system ready to run asynchronously.
pub struct AsyncCluster {
    graph: Graph,
    /// Per-node workload (objective + shard); logreg-homogeneous for
    /// the [`AsyncCluster::new`] constructor.
    plan: WorkloadPlan,
    /// Optional PJRT execution (native math when `None`).
    executor: Option<(ExecutorHandle, PjrtArtifacts)>,
}

impl AsyncCluster {
    pub fn new(graph: Graph, shards: Vec<Dataset>) -> Self {
        Self::from_plan(graph, WorkloadPlan::homogeneous(Objective::LogReg, shards))
    }

    /// A cluster over an explicit per-node workload (heterogeneous
    /// objectives and/or non-IID shards).
    pub fn from_plan(graph: Graph, plan: WorkloadPlan) -> Self {
        assert_eq!(graph.len(), plan.len());
        assert!(graph.is_connected(), "consensus needs a connected graph");
        Self {
            graph,
            plan,
            executor: None,
        }
    }

    /// Optimize a different §II objective (hinge-SVM, lasso) on every
    /// node.
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.plan = self.plan.with_uniform_objective(objective);
        self
    }

    /// Route gradient steps through a PJRT executor service. The
    /// artifact set must match the cluster's objective; checked at
    /// [`AsyncCluster::run`] so builder call order doesn't matter.
    pub fn with_executor(mut self, handle: ExecutorHandle, arts: PjrtArtifacts) -> Self {
        self.executor = Some((handle, arts));
        self
    }

    /// Run the cluster for `cfg.duration_secs`, snapshotting consensus +
    /// held-out error on a monitor thread.
    pub fn run(&self, cfg: &AsyncConfig, test: &Dataset) -> Result<AsyncReport> {
        // Compare families by name, not PartialEq: λ is a runtime input
        // staged per call, so artifacts are λ-agnostic and a custom
        // regularization strength must not abort the cluster.
        if let Some((_, arts)) = &self.executor {
            if self.plan.is_mixed() {
                bail!(
                    "PJRT executor artifacts are compiled per loss family; \
                     a mixed-objective plan must run on the native backend"
                );
            }
            if arts.objective.name() != self.plan.objective(0).name() {
                bail!(
                    "executor artifacts are for objective {}, but the cluster optimizes {}",
                    arts.objective.name(),
                    self.plan.objective(0).name()
                );
            }
        }
        let n = self.graph.len();
        let param_len = self.plan.param_len();
        let transport: Arc<dyn Transport> = match cfg.transport {
            TransportKind::SharedMem => Arc::new(SharedMem::new(n, param_len)),
            TransportKind::Channel => Arc::new(ChannelNet::with_round_budget(
                n,
                param_len,
                Duration::from_millis(100),
                Duration::from_secs_f64(cfg.gossip_hold_secs.max(0.0)),
            )),
            TransportKind::Socket => bail!(
                "transport 'socket' is the multi-process deployment and cannot be \
                 constructed inside a single-process cluster run; use \
                 `dasgd launch --workers K` (or `dasgd worker` per process) — \
                 see docs/deployment.md"
            ),
        };
        let run = spawn_shard(
            &self.graph,
            &self.plan,
            cfg,
            Arc::clone(&transport),
            0..n,
            self.executor.as_ref().map(|(h, a)| (h.clone(), a.clone())),
        );

        // Monitor loop (runs inline on the caller's thread).
        let probe = Probe::mixed(&self.plan.objectives(), test);
        let mut rec = Recorder::new("async");
        let sw = Stopwatch::new();
        let mut killed = 0usize;
        loop {
            let now = sw.elapsed_secs();
            if let Some(t_kill) = cfg.kill_after_secs {
                if now >= t_kill && killed == 0 && cfg.kill_nodes > 0 {
                    // Crash the first kill_nodes nodes: they stop acting
                    // and their variables become unreachable to gossip.
                    for i in 0..cfg.kill_nodes.min(n) {
                        run.kill(i);
                    }
                    killed = cfg.kill_nodes.min(n);
                }
            }
            // Metrics are computed over the *live* cohort only (a crashed
            // node's frozen variable is no longer part of the system).
            let params: Vec<Vec<f32>> = transport
                .snapshot()
                .into_iter()
                .enumerate()
                .filter(|(i, _)| run.alive(*i))
                .map(|(_, w)| w)
                .collect();
            rec.push(probe.snapshot(run.k(), now, &params, &run.counts()));
            if now >= cfg.duration_secs {
                break;
            }
            std::thread::sleep(Duration::from_secs_f64(
                cfg.eval_every_secs.min(cfg.duration_secs - now).max(0.01),
            ));
        }
        let counts = run.stop_and_join();
        let elapsed = sw.elapsed_secs();
        Ok(AsyncReport {
            killed,
            recorder: rec,
            updates: counts.updates(),
            grad_steps: counts.grad_steps,
            proj_steps: counts.proj_steps,
            conflicts: counts.conflicts,
            messages: counts.messages,
            updates_per_sec: counts.updates() as f64 / elapsed,
            final_params: transport.snapshot(),
        })
    }
}

/// One node's thread: fire on the exponential clock, act through the
/// transport, count in the canonical convention. `stepsize` is this
/// node's schedule (per-family for mixed plans, `cfg.stepsize`
/// otherwise).
#[allow(clippy::too_many_arguments)]
fn node_loop(
    mut logic: NodeLogic,
    rate_hz: f64,
    stepsize: StepSize,
    shared: Arc<Shared>,
    transport: Arc<dyn Transport>,
    graph: Graph,
    cfg: AsyncConfig,
    executor: Option<(ExecutorHandle, PjrtArtifacts)>,
    dim: usize,
    classes: usize,
) {
    let id = logic.id;
    let objective = logic.objective();
    let scale = logic.grad_scale();
    let hold = Duration::from_secs_f64(cfg.gossip_hold_secs.max(0.0));
    while !shared.stop.load(Ordering::Relaxed) {
        // Continuous-time §IV-A clock: wait Exp(rate).
        let wait = logic.wait_secs(rate_hz);
        std::thread::sleep(Duration::from_secs_f64(wait.min(0.05)));
        transport.poll(id);
        if shared.stop.load(Ordering::Relaxed) {
            return;
        }
        if !shared.alive[id].load(Ordering::Relaxed) {
            return; // crashed (fault injection)
        }
        if transport.busy(id) {
            continue; // captured by a neighbor's in-flight projection
        }
        let k = shared.k.load(Ordering::Relaxed);
        let lr = stepsize.at(k);
        match logic.draw_action() {
            Action::Grad => {
                // A streaming shard whose first block is still in
                // flight cannot step yet: skip and redraw (the node can
                // still join neighbors' projections meanwhile).
                if !logic.has_data() {
                    continue;
                }
                // Local gradient step: only our own variable (Eq. 6).
                match &executor {
                    None => transport.update_own(id, &mut |w| {
                        logic.native_grad_step(w, lr);
                    }),
                    Some((h, arts)) => {
                        let idx = logic.draw_index();
                        let label = logic.data().sample(idx).label;
                        let staged = objective.step_inputs(label, classes, lr, scale);
                        transport.update_own(id, &mut |w| {
                            let x = logic.data().sample(idx).features;
                            if let Ok(outs) =
                                h.execute_f32(&arts.step_b1, &staged.buffers(w.as_slice(), x))
                            {
                                *w = outs.into_iter().next().unwrap();
                            }
                        });
                    }
                }
                shared.grad_steps.fetch_add(1, Ordering::Relaxed);
                shared.k.fetch_add(1, Ordering::Relaxed);
            }
            Action::Project => {
                // Projection: §IV-C lock-up over the closed neighborhood
                // — restricted to live members (a crashed neighbor is
                // simply unreachable; the average is over whoever
                // answers). Liveness has two layers: fault-injected
                // kills in this process, and — for the multi-process
                // SocketNet — whole peer workers whose link is down.
                let hood: Vec<usize> = graph
                    .closed_neighborhood(id)
                    .into_iter()
                    .filter(|&j| shared.alive[j].load(Ordering::Relaxed) && transport.reachable(j))
                    .collect();
                if hood.len() < 2 {
                    continue; // nobody reachable to average with
                }
                let gossip = executor
                    .as_ref()
                    .and_then(|(h, arts)| arts.gossip.as_ref().map(|g| (h, g, arts)));
                let outcome = transport.try_project(id, &hood, hold, &mut |rows| {
                    // Compiled Eq. (7) when the artifact's padding fits,
                    // native averaging otherwise (identical semantics).
                    let staged = gossip.and_then(|(h, artifact, arts)| {
                        let k = objective.param_len(dim, classes);
                        arts.stage_gossip(rows, k)
                            .and_then(|(p, wts)| h.execute_f32(artifact, &[&p, &wts]).ok())
                    });
                    match staged {
                        Some(outs) => outs.into_iter().next().unwrap(),
                        None => neighborhood_average(rows),
                    }
                });
                match outcome {
                    ProjectionOutcome::Applied { participants } => {
                        shared
                            .messages
                            .fetch_add(projection_messages(participants), Ordering::Relaxed);
                        shared.proj_steps.fetch_add(1, Ordering::Relaxed);
                        shared.k.fetch_add(1, Ordering::Relaxed);
                    }
                    ProjectionOutcome::Conflict => {
                        // A member is mid-update: back off and redraw.
                        shared.conflicts.fetch_add(1, Ordering::Relaxed);
                    }
                    ProjectionOutcome::Isolated => {}
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;
    use crate::graph::regular_circulant;

    fn cluster(n: usize, k: usize, seed: u64) -> (AsyncCluster, Dataset) {
        let gen = SyntheticGen::new(n, 10, 4, 2.0, 0.5, 0.3, seed);
        let mut rng = Xoshiro256pp::seeded(seed);
        let shards = (0..n).map(|i| gen.node_dataset(i, 60, &mut rng)).collect();
        let test = gen.global_test_set(200, &mut rng);
        (AsyncCluster::new(regular_circulant(n, k), shards), test)
    }

    #[test]
    fn async_run_makes_progress_without_barriers() {
        let (c, test) = cluster(6, 2, 1);
        let cfg = AsyncConfig {
            duration_secs: 1.2,
            rate_hz: 400.0,
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 200, "updates={}", rep.updates);
        assert!(rep.grad_steps > 0 && rep.proj_steps > 0);
        let last = rep.recorder.last().unwrap();
        assert!(last.test_err < 0.7, "err={}", last.test_err);
        assert!(rep.updates_per_sec > 100.0);
    }

    #[test]
    fn heterogeneous_rates_still_converge() {
        let (c, test) = cluster(6, 4, 3);
        let cfg = AsyncConfig {
            duration_secs: 1.0,
            rate_hz: 300.0,
            speed_spread: 1.0, // ~3x rate disparity between nodes
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 100);
        // Consensus must still fall (async + stragglers don't break it).
        let first = rep.recorder.records.first().unwrap().consensus;
        let last = rep.recorder.last().unwrap().consensus;
        assert!(last <= first.max(1.0), "consensus {first} -> {last}");
    }

    #[test]
    fn survives_node_failures() {
        // The robustness claim: no server = no single point of failure.
        // Crash 2 of 8 nodes mid-run; the survivors keep updating and
        // still reach a useful model.
        let (c, test) = cluster(8, 4, 9);
        let cfg = AsyncConfig {
            duration_secs: 1.4,
            rate_hz: 400.0,
            kill_after_secs: Some(0.4),
            kill_nodes: 2,
            ..AsyncConfig::quick(8)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert_eq!(rep.killed, 2);
        // Updates continued well past the crash point.
        let at_kill = rep
            .recorder
            .records
            .iter()
            .find(|r| r.time_secs >= 0.4)
            .map(|r| r.grad_steps + r.proj_steps)
            .unwrap_or(0);
        assert!(
            rep.updates > at_kill + 50,
            "no progress after crash: {} vs {}",
            rep.updates,
            at_kill
        );
        // The surviving cohort still improves on random guessing.
        let last = rep.recorder.last().unwrap();
        assert!(last.test_err < 0.7, "err={}", last.test_err);
    }

    #[test]
    fn async_cluster_runs_hinge_objective() {
        // Same thread-per-node runtime, (dim)-shaped SVM parameters.
        let (c, test) = cluster(6, 2, 13);
        let c = c.with_objective(Objective::hinge());
        let cfg = AsyncConfig {
            duration_secs: 0.8,
            rate_hz: 400.0,
            stepsize: Objective::hinge().default_stepsize(6),
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 100, "updates={}", rep.updates);
        // Hinge parameter is (dim) = 10, not (dim × classes).
        assert!(rep.final_params.iter().all(|w| w.len() == 10));
        assert!(rep
            .final_params
            .iter()
            .all(|w| w.iter().all(|v| v.is_finite())));
        // The model moved off the all-zeros init.
        assert!(rep
            .final_params
            .iter()
            .any(|w| w.iter().any(|v| *v != 0.0)));
    }

    #[test]
    fn mixed_objective_plan_runs_heterogeneous_nodes() {
        // Hinge and lasso nodes share the (dim)-shaped parameter space
        // and gossip across family boundaries.
        use crate::workload::PlanSpec;
        let (plan, test) =
            PlanSpec::Mixed { alpha: 0.5 }.build(Objective::LogReg, 6, 60, 200, 17);
        let c = AsyncCluster::from_plan(regular_circulant(6, 2), plan);
        let cfg = AsyncConfig {
            duration_secs: 1.0,
            rate_hz: 400.0,
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 100, "updates={}", rep.updates);
        assert!(rep.proj_steps > 0, "no cross-family projection applied");
        // (dim)-shaped parameters, all finite.
        assert!(rep.final_params.iter().all(|w| w.len() == 50));
        assert!(rep
            .final_params
            .iter()
            .all(|w| w.iter().all(|v| v.is_finite())));
        let last = rep.recorder.last().unwrap();
        assert!(last.test_loss.is_finite() && last.test_err.is_finite());
    }

    #[test]
    fn lockup_conflicts_are_counted_under_contention() {
        // Dense graph + high rate = lots of neighborhood contention.
        let (c, test) = cluster(8, 6, 5);
        let cfg = AsyncConfig {
            duration_secs: 0.8,
            rate_hz: 2000.0,
            gossip_hold_secs: 0.002, // hold locks across a simulated RTT
            ..AsyncConfig::quick(8)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(
            rep.conflicts > 0,
            "expected lock-up conflicts under contention"
        );
        assert!(rep.messages > 0);
    }

    #[test]
    fn channel_transport_reaches_the_same_kind_of_model() {
        // The message-passing substrate: slower rounds (protocol + poll
        // cadence) but the same algorithm; the run must apply updates,
        // complete projections, and keep every vector finite.
        let (c, test) = cluster(6, 2, 21);
        let cfg = AsyncConfig {
            duration_secs: 1.5,
            rate_hz: 400.0,
            transport: TransportKind::Channel,
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 50, "updates={}", rep.updates);
        assert!(rep.grad_steps > 0);
        assert!(
            rep.proj_steps > 0,
            "no projection round completed over the channel transport"
        );
        assert!(rep
            .final_params
            .iter()
            .all(|w| w.iter().all(|v| v.is_finite())));
    }
}
