//! Asynchronous runtime — the system the paper argues for, with no
//! global clock and no barriers.
//!
//! Nodes are *tasks*, not threads. The default engine is a
//! work-stealing executor pool ([`EngineKind::Executors`]): a fixed set
//! of executor threads (one per CPU core unless `--executors N` says
//! otherwise) owns per-executor timer heaps of scheduled
//! [`NodeLogic`](crate::node_logic::NodeLogic) firings — a node's
//! exponential inter-event clock (the continuous-time limit of §IV-A's
//! geometric countdown; per-node rates model heterogeneous hardware)
//! becomes a scheduled wakeup instead of a parked OS thread, so one
//! worker drives thousands of nodes. An executor with nothing due
//! steals the most urgent due task from a backed-up peer. The
//! historical thread-per-node engine ([`EngineKind::ThreadPerNode`])
//! is kept as the baseline the scheduler is benchmarked and
//! trace-checked against.
//!
//! Either engine drives the same per-firing body ([`fire_node`]) over a
//! pluggable [`Transport`]:
//!
//! * [`TransportKind::SharedMem`] — sorted try-lock mutexes, the
//!   historical in-process substrate (behavior preserved bit-for-bit
//!   where seeds allow);
//! * [`TransportKind::Channel`] — message-passing collect/broadcast,
//!   the shape of a real deployment;
//! * [`TransportKind::Socket`] — the real deployment: constructed by
//!   `dasgd worker` / `dasgd launch` (see [`crate::net`]), where each
//!   process drives one shard of nodes via [`spawn_shard`] over a
//!   [`SocketNet`](crate::net::SocketNet).
//!
//! On firing, a node performs a gradient step (w.p. `p_grad`) on its
//! own variable, or a §IV-C lock-up + Eq. (7) projection over its
//! closed neighborhood. A busy neighborhood means *back off and redraw*
//! (a counted conflict), never a deadlock. Messages are counted in the
//! canonical [`crate::node_logic`] convention: `2·(h−1)` per applied
//! projection, nothing for aborts.
//!
//! Gradient/projection math runs rust-native by default or through the
//! channel-based [`ExecutorHandle`](crate::runtime::ExecutorHandle)
//! (one PJRT engine per executor thread) when an executor is supplied.
//! Under the pool engine a backlogged node — one whose wakeup fired
//! [`STEP_BATCH`] or more periods late — collapses its owed gradient
//! firings into a single compiled batch-8 step (`step_b8`, the
//! linear-scaling rule), so falling behind costs one PJRT dispatch
//! instead of eight.

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::data::stream::BlockBuffer;
use crate::data::Dataset;
use crate::graph::Graph;
use crate::membership::TopologyView;
use crate::metrics::Recorder;
use crate::node_logic::{projection_messages, Action, Counts, NodeLogic, Probe, Strategy};
use crate::objective::Objective;
use crate::runtime::ExecutorHandle;
use crate::transport::{
    ChannelNet, ProjectionOutcome, SharedMem, Transport, TransportKind,
};
use crate::util::rng::Xoshiro256pp;
use crate::util::Stopwatch;
use crate::workload::WorkloadPlan;

use super::backend::{PjrtArtifacts, STEP_BATCH};
use super::config::StepSize;

/// Which node-driving engine executes a shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// One OS thread per node — the historical engine. Kept as the
    /// baseline the executor pool is benchmarked and trace-checked
    /// against; saturates at a few hundred nodes per process.
    ThreadPerNode,
    /// Work-stealing executor pool driving node tasks off per-executor
    /// timer heaps. `0` = one executor per available CPU core
    /// (`--executors N` overrides).
    Executors(usize),
}

impl EngineKind {
    /// Number of executor threads to run for `tasks` node tasks.
    fn pool_size(want: usize, tasks: usize) -> usize {
        let auto = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4);
        let n = if want == 0 { auto } else { want };
        n.min(tasks).max(1)
    }
}

/// Configuration of an asynchronous run.
#[derive(Clone, Debug)]
pub struct AsyncConfig {
    /// Gradient-step probability (paper: 0.5).
    pub p_grad: f64,
    pub stepsize: StepSize,
    /// Mean firing rate per node, events/second.
    pub rate_hz: f64,
    /// Heterogeneity: node i's rate is `rate_hz · exp(N(0, spread))` —
    /// spread 0 = homogeneous cluster, 1 ≈ mixed servers + phones.
    pub speed_spread: f64,
    /// Run length (wall-clock seconds).
    pub duration_secs: f64,
    /// Snapshot cadence for the monitor thread.
    pub eval_every_secs: f64,
    /// Simulated network hold time while a projection's locks are held
    /// (models the collect/broadcast RTT of a real deployment; 0 = the
    /// in-process memory-speed limit).
    pub gossip_hold_secs: f64,
    /// Fault injection: kill this many nodes after the given time — the
    /// paper's robustness motivation (no server = no single point of
    /// failure). Killed nodes stop updating and become unreachable to
    /// their neighbors' gossip; the survivors keep converging.
    pub kill_after_secs: Option<f64>,
    pub kill_nodes: usize,
    /// Which communication substrate the node tasks run on.
    pub transport: TransportKind,
    /// Which engine drives the node tasks (`--executors N`).
    pub engine: EngineKind,
    /// Deterministic replay: fire exactly this many events in global
    /// virtual-time order — `(next_fire, node_id)`, where every wakeup
    /// derives from the node's own `(seed, id)` RNG — then stop. Both
    /// engines honor it (the pool runs one executor in virtual time;
    /// thread-per-node serializes through a sequencer gate), so a
    /// fixed seed yields bit-identical trajectories across engines.
    /// Meant for `SharedMem` (cross-engine equivalence tests); wall
    /// clocks and `duration_secs` are ignored while set.
    pub deterministic_events: Option<u64>,
    pub seed: u64,
}

impl AsyncConfig {
    pub fn quick(n_nodes: usize) -> Self {
        Self {
            p_grad: 0.5,
            stepsize: StepSize::paper_default(n_nodes),
            rate_hz: 200.0,
            speed_spread: 0.0,
            duration_secs: 1.0,
            eval_every_secs: 0.25,
            gossip_hold_secs: 0.0,
            kill_after_secs: None,
            kill_nodes: 0,
            transport: TransportKind::SharedMem,
            engine: EngineKind::Executors(0),
            deterministic_events: None,
            seed: 0,
        }
    }
}

/// Outcome of an asynchronous run.
#[derive(Debug)]
pub struct AsyncReport {
    /// Nodes crashed by fault injection during the run.
    pub killed: usize,
    pub recorder: Recorder,
    pub updates: u64,
    pub grad_steps: u64,
    pub proj_steps: u64,
    /// Projection attempts aborted because the neighborhood was busy.
    pub conflicts: u64,
    pub messages: u64,
    pub updates_per_sec: f64,
    /// Final per-node parameters.
    pub final_params: Vec<Vec<f32>>,
}

/// Cross-thread run state: liveness, stop flag, and the shared counters
/// (parameters live in the [`Transport`]).
struct Shared {
    /// Per-node liveness: false = crashed (fault injection).
    alive: Vec<AtomicBool>,
    stop: AtomicBool,
    grad_steps: AtomicU64,
    proj_steps: AtomicU64,
    conflicts: AtomicU64,
    messages: AtomicU64,
    /// Applied-update counter across this process's node tasks (for
    /// stepsize decay; in a multi-process deployment each worker decays
    /// on its local counter).
    k: AtomicU64,
}

impl Shared {
    fn new(n: usize) -> Self {
        Self {
            alive: (0..n).map(|_| AtomicBool::new(true)).collect(),
            stop: AtomicBool::new(false),
            grad_steps: AtomicU64::new(0),
            proj_steps: AtomicU64::new(0),
            conflicts: AtomicU64::new(0),
            messages: AtomicU64::new(0),
            k: AtomicU64::new(0),
        }
    }

    fn counts(&self) -> Counts {
        Counts {
            grad_steps: self.grad_steps.load(Ordering::Relaxed),
            proj_steps: self.proj_steps.load(Ordering::Relaxed),
            messages: self.messages.load(Ordering::Relaxed),
            conflicts: self.conflicts.load(Ordering::Relaxed),
        }
    }
}

/// A running engine driving one *shard* of the system — every node for
/// the in-process engines, one worker's block for the multi-process
/// [`SocketNet`](crate::net::SocketNet) deployment. Obtained from
/// [`spawn_shard`]; stop with [`ShardRun::stop`] + [`ShardRun::join`].
/// The handles are executor threads under the pool engine, one thread
/// per node under [`EngineKind::ThreadPerNode`] — callers cannot tell
/// the difference.
pub struct ShardRun {
    shared: Arc<Shared>,
    topology: Arc<TopologyView>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ShardRun {
    /// Cumulative counters in the canonical convention.
    pub fn counts(&self) -> Counts {
        self.shared.counts()
    }

    /// The live topology the node tasks sample their neighborhoods
    /// from. Membership repair applies
    /// [`TopologyPatch`](crate::net::WireMsg::TopologyPatch) frames
    /// here; each collect round reads one consistent neighborhood, so
    /// a patch can land mid-run without tearing an in-flight round.
    pub fn topology(&self) -> &Arc<TopologyView> {
        &self.topology
    }

    /// Applied updates so far (this shard's stepsize clock).
    pub fn k(&self) -> u64 {
        self.shared.k.load(Ordering::Relaxed)
    }

    /// Fault injection: crash node `id` (it stops acting and becomes
    /// unreachable to its neighbors' gossip).
    pub fn kill(&self, id: usize) {
        self.shared.alive[id].store(false, Ordering::SeqCst);
    }

    pub fn alive(&self, id: usize) -> bool {
        self.shared.alive[id].load(Ordering::Relaxed)
    }

    /// Ask the engine to stop after the current firings.
    pub fn stop(&self) {
        self.shared.stop.store(true, Ordering::SeqCst);
    }

    /// Wait for the engine threads ([`ShardRun::stop`] first, or this
    /// blocks until something else stops them).
    pub fn join(self) {
        for h in self.handles {
            h.join().expect("engine thread panicked");
        }
    }

    /// Stop, wait for the engine, and return the final counters (read
    /// *after* the join, so no late increment is missed).
    pub fn stop_and_join(self) -> Counts {
        self.stop();
        let shared = Arc::clone(&self.shared);
        self.join();
        shared.counts()
    }
}

/// The RNG stream node `i` consumes. Derived from the run seed and the
/// node id alone — independent of spawn order, sharding, *and engine* —
/// so every worker of a sharded deployment reproduces exactly the
/// per-node streams a single-process run with the same seed would use.
fn node_rng(seed: u64, i: usize) -> Xoshiro256pp {
    Xoshiro256pp::seeded(seed).split(i as u64)
}

/// Everything a firing needs besides the node's own task state. Shared
/// by both engines so their per-event behavior cannot drift apart.
struct FireCtx {
    shared: Arc<Shared>,
    transport: Arc<dyn Transport>,
    /// The (patchable) communication topology — launch graph at
    /// version 0, rewritten by membership repair patches mid-run.
    topology: Arc<TopologyView>,
    cfg: AsyncConfig,
    executor: Option<(ExecutorHandle, PjrtArtifacts)>,
    dim: usize,
    classes: usize,
}

/// One schedulable node: its logic, its heterogeneous firing rate, its
/// stepsize schedule (per-family for mixed plans), and its update
/// [`Strategy`] (per-node, from the plan — see docs/algorithms.md).
struct Task {
    logic: NodeLogic,
    rate_hz: f64,
    stepsize: StepSize,
    strategy: Box<dyn Strategy>,
    /// The shared applied-update count (`Shared::k`) observed the last
    /// time this node applied an update — the baseline for the
    /// gradient-staleness histogram (`obs::Hist::StalenessTicks`) and
    /// the staleness signal delay-aware strategies consume.
    last_k: u64,
}

impl Task {
    /// Next inter-fire delay: the node's own Exp(rate) draw, capped at
    /// 50 ms so stop flags and transport polls are serviced at least
    /// 20×/s (the cap the thread-per-node engine has always applied).
    fn delay(&mut self) -> f64 {
        self.logic.wait_secs(self.rate_hz).min(0.05)
    }
}

/// Spawn the configured engine over one node task per id in `owned`,
/// each driving a [`NodeLogic`] built from its [`WorkloadPlan`]
/// assignment (objective + shard) over `transport`. The
/// engine-construction primitive behind [`AsyncCluster::run`] (owned =
/// all nodes) and the multi-process worker (`dasgd worker`; owned = the
/// worker's shard block).
///
/// Homogeneous plans use `cfg.stepsize` everywhere; mixed plans give
/// each node its own family's default schedule (one hinge-stable step
/// would overshoot the Lasso curvature bound — see
/// docs/heterogeneity.md).
pub fn spawn_shard(
    graph: &Graph,
    plan: &WorkloadPlan,
    cfg: &AsyncConfig,
    transport: Arc<dyn Transport>,
    owned: std::ops::Range<usize>,
    executor: Option<(ExecutorHandle, PjrtArtifacts)>,
) -> ShardRun {
    spawn_shard_with_feeds(graph, plan, cfg, transport, owned, executor, None)
}

/// [`spawn_shard`] for streamed plans: when `feeds` is given, each
/// owned node's [`NodeLogic`] starts with an *empty* shard fed by that
/// node's [`BlockBuffer`] receiver — the node steps as soon as its
/// first `ShardBlock` lands instead of waiting for the whole shard
/// (the plan's assignments then carry metadata only). `None` is the
/// historical fully-shipped path, bit-for-bit unchanged.
pub fn spawn_shard_with_feeds(
    graph: &Graph,
    plan: &WorkloadPlan,
    cfg: &AsyncConfig,
    transport: Arc<dyn Transport>,
    owned: std::ops::Range<usize>,
    executor: Option<(ExecutorHandle, PjrtArtifacts)>,
    feeds: Option<&Arc<BlockBuffer>>,
) -> ShardRun {
    let n = graph.len();
    assert_eq!(plan.len(), n, "one workload assignment per node");
    assert!(owned.end <= n);
    let (dim, classes) = (plan.dim(), plan.classes());
    let mixed = plan.is_mixed();
    let shared = Arc::new(Shared::new(n));
    let topology = Arc::new(TopologyView::new(graph.clone()));
    let ctx = Arc::new(FireCtx {
        shared: Arc::clone(&shared),
        transport,
        topology: Arc::clone(&topology),
        cfg: cfg.clone(),
        executor,
        dim,
        classes,
    });
    let mut tasks = Vec::with_capacity(owned.len());
    for i in owned {
        let mut rng = node_rng(cfg.seed, i);
        let rate = cfg.rate_hz * (rng.next_gauss() * cfg.speed_spread).exp();
        let a = plan.node(i);
        let logic = match feeds {
            Some(buffer) => NodeLogic::streaming(
                i,
                a.objective,
                cfg.p_grad,
                buffer.receiver(i),
                dim,
                classes,
                n,
                rng,
            ),
            None => NodeLogic::new(i, a.objective, cfg.p_grad, a.shard.clone(), n, rng),
        };
        let stepsize = if mixed {
            a.objective.default_stepsize(n)
        } else {
            cfg.stepsize
        };
        let strategy = a.strategy.build(stepsize.at(0));
        tasks.push(Task {
            logic,
            rate_hz: rate,
            stepsize,
            strategy,
            last_k: 0,
        });
    }
    let handles = match cfg.engine {
        EngineKind::ThreadPerNode => spawn_thread_per_node(tasks, ctx),
        EngineKind::Executors(want) => spawn_executor_pool(tasks, ctx, want),
    };
    ShardRun {
        shared,
        topology,
        handles,
    }
}

// ---------------------------------------------------------------------------
// The per-firing body, shared by both engines.
// ---------------------------------------------------------------------------

/// One firing of one node: poll the transport, gate on liveness and
/// capture, draw the action, and perform it (counting in the canonical
/// convention). Returns `false` when the node is done for good
/// (crashed) and must not be rescheduled.
///
/// `owed` is how many firings this wakeup stands for — always 1 except
/// when the pool engine is running behind (see [`STEP_BATCH`]); a
/// backlogged PJRT gradient collapses into one compiled batch step at
/// `owed·lr` (the linear-scaling rule: a mean-gradient step over
/// `owed` samples at `owed·lr` matches `owed` sequential steps at `lr`
/// to first order).
fn fire_node(ctx: &FireCtx, task: &mut Task, owed: u64) -> bool {
    let stepsize = task.stepsize;
    let Task {
        logic,
        strategy,
        last_k,
        ..
    } = task;
    let id = logic.id;
    let objective = logic.objective();
    let scale = logic.grad_scale();
    let hold = Duration::from_secs_f64(ctx.cfg.gossip_hold_secs.max(0.0));
    // Observability only: timestamps and counters never feed back into
    // scheduling or RNG state, so deterministic replays stay bit-exact.
    let fired_at = Instant::now();
    ctx.transport.poll(id);
    if ctx.shared.stop.load(Ordering::Relaxed) {
        return true;
    }
    if !ctx.shared.alive[id].load(Ordering::Relaxed) {
        return false; // crashed (fault injection)
    }
    if ctx.transport.busy(id) {
        return true; // captured by a neighbor's in-flight projection
    }
    let k = ctx.shared.k.load(Ordering::Relaxed);
    let lr = stepsize.at(k);
    // Staleness in applied-update ticks since this node's own last
    // applied update — computed before the action draw so the obs
    // histogram and the delay-aware strategies read one signal.
    let staleness = k.saturating_sub(*last_k);
    match strategy.draw_action(logic) {
        Action::Grad => {
            // A streaming shard whose first block is still in flight
            // cannot step yet: skip and redraw (the node can still join
            // neighbors' projections meanwhile).
            if !logic.has_data() {
                return true;
            }
            // Local step on our own variable: Eq. (6) for the baseline,
            // the strategy's rule otherwise. Compiled PJRT steps encode
            // exactly the baseline's math, so every other strategy runs
            // the native path even when an executor is attached.
            match ctx.executor.as_ref().filter(|_| strategy.pjrt_compatible()) {
                None => ctx.transport.update_own_with_aux(id, &mut |w, aux| {
                    strategy.local_step(logic, w, aux, lr, staleness);
                }),
                Some((h, arts)) => {
                    let batch = arts
                        .step_b8
                        .as_deref()
                        .filter(|_| owed >= STEP_BATCH as u64);
                    if let Some(artifact) = batch {
                        // Backlog collapse: one batch-8 mean-gradient
                        // step at 8·lr in place of the 8 owed firings.
                        let idxs: Vec<usize> =
                            (0..STEP_BATCH).map(|_| logic.draw_index()).collect();
                        let labels: Vec<usize> = idxs
                            .iter()
                            .map(|&i| logic.data().sample(i).label)
                            .collect();
                        let staged =
                            objective.step_inputs_batch(&labels, ctx.classes, lr, scale);
                        ctx.transport.update_own(id, &mut |w| {
                            let mut x = Vec::with_capacity(STEP_BATCH * ctx.dim);
                            for &i in &idxs {
                                x.extend_from_slice(logic.data().sample(i).features);
                            }
                            if let Ok(outs) =
                                h.execute_f32(artifact, &staged.buffers(w.as_slice(), &x))
                            {
                                *w = outs.into_iter().next().unwrap();
                            }
                        });
                        ctx.shared
                            .grad_steps
                            .fetch_add(STEP_BATCH as u64, Ordering::Relaxed);
                        ctx.shared.k.fetch_add(STEP_BATCH as u64, Ordering::Relaxed);
                        crate::obs::add(crate::obs::Counter::B8Collapses, 1);
                        crate::obs::observe(crate::obs::Hist::StalenessTicks, staleness);
                        crate::obs::observe(
                            crate::obs::Hist::FireToApplyUs,
                            fired_at.elapsed().as_micros() as u64,
                        );
                        *last_k = k;
                        crate::obs::trace("node", "grad_b8", id as u64, owed);
                        return true;
                    }
                    let idx = logic.draw_index();
                    let label = logic.data().sample(idx).label;
                    let staged = objective.step_inputs(label, ctx.classes, lr, scale);
                    ctx.transport.update_own(id, &mut |w| {
                        let x = logic.data().sample(idx).features;
                        if let Ok(outs) =
                            h.execute_f32(&arts.step_b1, &staged.buffers(w.as_slice(), x))
                        {
                            *w = outs.into_iter().next().unwrap();
                        }
                    });
                }
            }
            ctx.shared.grad_steps.fetch_add(1, Ordering::Relaxed);
            ctx.shared.k.fetch_add(1, Ordering::Relaxed);
            crate::obs::observe(crate::obs::Hist::StalenessTicks, staleness);
            crate::obs::observe(
                crate::obs::Hist::FireToApplyUs,
                fired_at.elapsed().as_micros() as u64,
            );
            *last_k = k;
            crate::obs::trace("node", "grad", id as u64, owed);
        }
        Action::Project => {
            // Projection: §IV-C lock-up over the closed neighborhood —
            // restricted to live members (a crashed neighbor is simply
            // unreachable; the average is over whoever answers).
            // Liveness has two layers: fault-injected kills in this
            // process, and — for the multi-process SocketNet — whole
            // peer workers whose link is down.
            let hood: Vec<usize> = ctx
                .topology
                .closed_neighborhood(id)
                .into_iter()
                .filter(|&j| {
                    ctx.shared.alive[j].load(Ordering::Relaxed) && ctx.transport.reachable(j)
                })
                .collect();
            if hood.len() < 2 {
                return true; // nobody reachable to average with
            }
            let gossip = ctx
                .executor
                .as_ref()
                .and_then(|(h, arts)| arts.gossip.as_ref().map(|g| (h, g, arts)));
            let outcome = ctx.transport.try_project(id, &hood, hold, &mut |rows, aux_rows| {
                // Compiled Eq. (7) when the artifact's padding fits and
                // the strategy's mix *is* the plain neighborhood average;
                // the strategy's native mix rule otherwise.
                let staged = gossip
                    .filter(|_| strategy.pjrt_compatible())
                    .and_then(|(h, artifact, arts)| {
                        let k = objective.param_len(ctx.dim, ctx.classes);
                        arts.stage_gossip(rows, k)
                            .and_then(|(p, wts)| h.execute_f32(artifact, &[&p, &wts]).ok())
                    });
                match staged {
                    Some(outs) => (outs.into_iter().next().unwrap(), Vec::new()),
                    None => strategy.mix(rows, aux_rows),
                }
            });
            match outcome {
                ProjectionOutcome::Applied { participants } => {
                    ctx.shared
                        .messages
                        .fetch_add(projection_messages(participants), Ordering::Relaxed);
                    ctx.shared.proj_steps.fetch_add(1, Ordering::Relaxed);
                    ctx.shared.k.fetch_add(1, Ordering::Relaxed);
                    crate::obs::observe(crate::obs::Hist::StalenessTicks, staleness);
                    crate::obs::observe(
                        crate::obs::Hist::FireToApplyUs,
                        fired_at.elapsed().as_micros() as u64,
                    );
                    *last_k = k;
                    crate::obs::trace("node", "apply", id as u64, participants as u64);
                }
                ProjectionOutcome::Conflict => {
                    // A member is mid-update: back off and redraw.
                    ctx.shared.conflicts.fetch_add(1, Ordering::Relaxed);
                    crate::obs::add(crate::obs::Counter::Conflicts, 1);
                    crate::obs::trace("node", "conflict", id as u64, 0);
                }
                ProjectionOutcome::Isolated => {}
            }
        }
    }
    true
}

// ---------------------------------------------------------------------------
// Engine 1: thread-per-node (baseline).
// ---------------------------------------------------------------------------

/// Serialization gate for deterministic thread-per-node runs: node
/// threads register their next virtual fire time and block until theirs
/// is the global minimum `(time, id)` *and* no other body is running —
/// so firings execute one at a time in exactly the order the
/// single-executor pool would schedule them.
struct Sequencer {
    state: Mutex<SeqState>,
    cv: Condvar,
}

struct SeqState {
    /// Pending `(fire_time_bits, node_id)` entries (f64 bit patterns
    /// order like the non-negative floats they encode).
    pending: BTreeSet<(u64, usize)>,
    running: bool,
    fired: u64,
    budget: u64,
}

impl Sequencer {
    fn new(budget: u64) -> Self {
        Self {
            state: Mutex::new(SeqState {
                pending: BTreeSet::new(),
                running: false,
                fired: 0,
                budget,
            }),
            cv: Condvar::new(),
        }
    }

    /// Register `(at, id)` and block until it is this thread's turn.
    /// Returns false (entry withdrawn) once the event budget is spent
    /// or the run is stopping — the caller exits.
    fn next_turn(&self, at: f64, id: usize, stop: &AtomicBool) -> bool {
        let key = (at.to_bits(), id);
        let mut s = self.state.lock().unwrap();
        s.pending.insert(key);
        self.cv.notify_all();
        loop {
            if s.fired >= s.budget || stop.load(Ordering::Relaxed) {
                s.pending.remove(&key);
                stop.store(true, Ordering::SeqCst);
                self.cv.notify_all();
                return false;
            }
            if !s.running && s.pending.first() == Some(&key) {
                s.pending.remove(&key);
                s.running = true;
                s.fired += 1;
                return true;
            }
            s = self.cv.wait(s).unwrap();
        }
    }

    /// The body finished: hand the turn to the next minimum.
    fn done(&self) {
        let mut s = self.state.lock().unwrap();
        s.running = false;
        drop(s);
        self.cv.notify_all();
    }
}

fn spawn_thread_per_node(
    tasks: Vec<Task>,
    ctx: Arc<FireCtx>,
) -> Vec<std::thread::JoinHandle<()>> {
    let seq = ctx
        .cfg
        .deterministic_events
        .map(|budget| Arc::new(Sequencer::new(budget)));
    tasks
        .into_iter()
        .map(|task| {
            let ctx = Arc::clone(&ctx);
            let seq = seq.clone();
            std::thread::spawn(move || node_loop(task, ctx, seq))
        })
        .collect()
}

/// One node's thread: fire on the exponential clock, act through the
/// transport. With a [`Sequencer`] (deterministic runs) the clock is
/// virtual and firings serialize in global `(time, id)` order; without
/// one the thread sleeps its capped delay for real.
fn node_loop(mut task: Task, ctx: Arc<FireCtx>, seq: Option<Arc<Sequencer>>) {
    let id = task.logic.id;
    let mut vt = 0.0f64;
    while !ctx.shared.stop.load(Ordering::Relaxed) {
        let delay = task.delay();
        match &seq {
            None => std::thread::sleep(Duration::from_secs_f64(delay)),
            Some(s) => {
                vt += delay;
                if !s.next_turn(vt, id, &ctx.shared.stop) {
                    return;
                }
            }
        }
        let keep = fire_node(&ctx, &mut task, 1);
        if let Some(s) = &seq {
            s.done();
        }
        if !keep {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// Engine 2: the work-stealing executor pool (default).
// ---------------------------------------------------------------------------

/// A scheduled firing: min-ordered by `(at, id)` — the id tiebreak is
/// what makes single-executor order deterministic.
struct TimerEntry {
    /// Seconds since run start (wall-clock target, or accumulated
    /// virtual time under `deterministic_events`).
    at: f64,
    id: usize,
    task: Task,
}

impl TimerEntry {
    fn key(&self) -> (u64, usize) {
        // Non-negative f64 bit patterns order like the floats.
        (self.at.to_bits(), self.id)
    }
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.key() == other.key()
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

/// Per-executor timer heaps. An entry's due-ness is its position
/// against the shared run clock; the due prefix of each heap *is* that
/// executor's ready queue, and stealing pops the most urgent due entry
/// from a backed-up peer.
struct Pool {
    slots: Vec<Mutex<BinaryHeap<Reverse<TimerEntry>>>>,
    start: Instant,
}

impl Pool {
    fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    fn push(&self, slot: usize, entry: TimerEntry) {
        self.slots[slot].lock().unwrap().push(Reverse(entry));
    }

    /// Pop `slot`'s earliest entry if it is due at `now`.
    fn pop_due(&self, slot: usize, now: f64) -> Option<TimerEntry> {
        let mut heap = self.slots[slot].lock().unwrap();
        if heap.peek().map(|Reverse(e)| e.at <= now).unwrap_or(false) {
            heap.pop().map(|Reverse(e)| e)
        } else {
            None
        }
    }

    /// When `slot`'s next entry fires, if any.
    fn next_at(&self, slot: usize) -> Option<f64> {
        self.slots[slot].lock().unwrap().peek().map(|Reverse(e)| e.at)
    }
}

fn spawn_executor_pool(
    mut tasks: Vec<Task>,
    ctx: Arc<FireCtx>,
    want: usize,
) -> Vec<std::thread::JoinHandle<()>> {
    if tasks.is_empty() {
        return Vec::new();
    }
    if let Some(budget) = ctx.cfg.deterministic_events {
        // Deterministic replay runs one executor in virtual time —
        // global (next_fire, id) order with no wall clock at all.
        let mut heap = BinaryHeap::new();
        for mut task in tasks {
            let at = task.delay();
            let id = task.logic.id;
            heap.push(Reverse(TimerEntry { at, id, task }));
        }
        return vec![std::thread::spawn(move || {
            deterministic_executor(heap, ctx, budget)
        })];
    }
    let n_exec = EngineKind::pool_size(want, tasks.len());
    let pool = Arc::new(Pool {
        slots: (0..n_exec).map(|_| Mutex::new(BinaryHeap::new())).collect(),
        start: Instant::now(),
    });
    // Round-robin the initial wakeups over the executors; stealing
    // rebalances from there.
    for (i, mut task) in tasks.drain(..).enumerate() {
        let at = task.delay();
        let id = task.logic.id;
        pool.push(i % n_exec, TimerEntry { at, id, task });
    }
    (0..n_exec)
        .map(|ex| {
            let pool = Arc::clone(&pool);
            let ctx = Arc::clone(&ctx);
            std::thread::spawn(move || executor_loop(ex, pool, ctx))
        })
        .collect()
}

/// One executor thread: run due tasks from its own timer heap, steal
/// the most urgent due task from a peer when it has none, sleep until
/// its next wakeup otherwise.
fn executor_loop(ex: usize, pool: Arc<Pool>, ctx: Arc<FireCtx>) {
    let n_slots = pool.slots.len();
    while !ctx.shared.stop.load(Ordering::Relaxed) {
        let now = pool.now();
        let mut entry = pool.pop_due(ex, now);
        if entry.is_none() {
            // Nothing due here: steal from a backed-up peer.
            for off in 1..n_slots {
                entry = pool.pop_due((ex + off) % n_slots, now);
                if entry.is_some() {
                    crate::obs::add(crate::obs::Counter::Steals, 1);
                    break;
                }
            }
        }
        let Some(TimerEntry { at, id, mut task }) = entry else {
            // Idle: sleep until our next wakeup (bounded so stop flags
            // and steal opportunities are noticed promptly).
            let until = pool.next_at(ex).unwrap_or(now + 0.005);
            let dur = (until - now).clamp(0.0001, 0.005);
            std::thread::sleep(Duration::from_secs_f64(dur));
            continue;
        };
        // How late is this wakeup, in units of the node's mean capped
        // period? A task ≥ STEP_BATCH periods behind owes that many
        // firings — fire_node collapses them into one batched gradient
        // step on the PJRT path.
        let period = (1.0 / task.rate_hz.max(1e-9)).min(0.05);
        let owed = if now - at >= period * STEP_BATCH as f64 {
            STEP_BATCH as u64
        } else {
            1
        };
        // How far past its deadline did this wakeup pop? (Timer-heap
        // lag; clamps at zero — an early poll never goes negative.)
        if now > at {
            crate::obs::observe(
                crate::obs::Hist::TimerLagUs,
                ((now - at) * 1e6) as u64,
            );
        }
        let keep = fire_node(&ctx, &mut task, owed);
        if !keep {
            continue; // crashed — drop the task
        }
        let delay = task.delay();
        let next = pool.now() + delay;
        pool.push(ex, TimerEntry { at: next, id, task });
    }
}

/// The single-executor virtual-time engine behind
/// `deterministic_events`: pop the global minimum `(at, id)`, fire,
/// reschedule at `at + delay` — no sleeping, no wall clock.
fn deterministic_executor(
    mut heap: BinaryHeap<Reverse<TimerEntry>>,
    ctx: Arc<FireCtx>,
    budget: u64,
) {
    let mut fired = 0u64;
    while fired < budget && !ctx.shared.stop.load(Ordering::Relaxed) {
        let Some(Reverse(TimerEntry { at, id, mut task })) = heap.pop() else {
            break; // every node crashed
        };
        let keep = fire_node(&ctx, &mut task, 1);
        fired += 1;
        if keep {
            let next = at + task.delay();
            heap.push(Reverse(TimerEntry { at: next, id, task }));
        }
    }
    ctx.shared.stop.store(true, Ordering::SeqCst);
}

/// A networked system ready to run asynchronously.
pub struct AsyncCluster {
    graph: Graph,
    /// Per-node workload (objective + shard); logreg-homogeneous for
    /// the [`AsyncCluster::new`] constructor.
    plan: WorkloadPlan,
    /// Optional PJRT execution (native math when `None`).
    executor: Option<(ExecutorHandle, PjrtArtifacts)>,
}

impl AsyncCluster {
    pub fn new(graph: Graph, shards: Vec<Dataset>) -> Self {
        Self::from_plan(graph, WorkloadPlan::homogeneous(Objective::LogReg, shards))
    }

    /// A cluster over an explicit per-node workload (heterogeneous
    /// objectives and/or non-IID shards).
    pub fn from_plan(graph: Graph, plan: WorkloadPlan) -> Self {
        assert_eq!(graph.len(), plan.len());
        assert!(graph.is_connected(), "consensus needs a connected graph");
        Self {
            graph,
            plan,
            executor: None,
        }
    }

    /// Optimize a different §II objective (hinge-SVM, lasso) on every
    /// node.
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.plan = self.plan.with_uniform_objective(objective);
        self
    }

    /// Route gradient steps through a PJRT executor service. The
    /// artifact set must match the cluster's objective; checked at
    /// [`AsyncCluster::run`] so builder call order doesn't matter.
    pub fn with_executor(mut self, handle: ExecutorHandle, arts: PjrtArtifacts) -> Self {
        self.executor = Some((handle, arts));
        self
    }

    /// Run the cluster for `cfg.duration_secs`, snapshotting consensus +
    /// held-out error on a monitor thread.
    pub fn run(&self, cfg: &AsyncConfig, test: &Dataset) -> Result<AsyncReport> {
        // Compare families by name, not PartialEq: λ is a runtime input
        // staged per call, so artifacts are λ-agnostic and a custom
        // regularization strength must not abort the cluster.
        if let Some((_, arts)) = &self.executor {
            if self.plan.is_mixed() {
                bail!(
                    "PJRT executor artifacts are compiled per loss family; \
                     a mixed-objective plan must run on the native backend"
                );
            }
            if arts.objective.name() != self.plan.objective(0).name() {
                bail!(
                    "executor artifacts are for objective {}, but the cluster optimizes {}",
                    arts.objective.name(),
                    self.plan.objective(0).name()
                );
            }
        }
        let n = self.graph.len();
        let param_len = self.plan.param_len();
        let transport: Arc<dyn Transport> = match cfg.transport {
            TransportKind::SharedMem => Arc::new(SharedMem::new(n, param_len)),
            TransportKind::Channel => Arc::new(ChannelNet::with_round_budget(
                n,
                param_len,
                Duration::from_millis(100),
                Duration::from_secs_f64(cfg.gossip_hold_secs.max(0.0)),
            )),
            TransportKind::Socket => bail!(
                "transport 'socket' is the multi-process deployment and cannot be \
                 constructed inside a single-process cluster run; use \
                 `dasgd launch --workers K` (or `dasgd worker` per process) — \
                 see docs/deployment.md"
            ),
        };
        let run = spawn_shard(
            &self.graph,
            &self.plan,
            cfg,
            Arc::clone(&transport),
            0..n,
            self.executor.as_ref().map(|(h, a)| (h.clone(), a.clone())),
        );

        // Monitor loop (runs inline on the caller's thread).
        let probe = Probe::mixed(&self.plan.objectives(), test);
        let mut rec = Recorder::new("async");
        let sw = Stopwatch::new();
        let mut killed = 0usize;
        loop {
            let now = sw.elapsed_secs();
            if let Some(t_kill) = cfg.kill_after_secs {
                if now >= t_kill && killed == 0 && cfg.kill_nodes > 0 {
                    // Crash the first kill_nodes nodes: they stop acting
                    // and their variables become unreachable to gossip.
                    for i in 0..cfg.kill_nodes.min(n) {
                        run.kill(i);
                    }
                    killed = cfg.kill_nodes.min(n);
                }
            }
            // Metrics are computed over the *live* cohort only (a crashed
            // node's frozen variable is no longer part of the system).
            let params: Vec<Vec<f32>> = transport
                .snapshot()
                .into_iter()
                .enumerate()
                .filter(|(i, _)| run.alive(*i))
                .map(|(_, w)| w)
                .collect();
            rec.push(probe.snapshot(run.k(), now, &params, &run.counts()));
            if now >= cfg.duration_secs {
                break;
            }
            std::thread::sleep(Duration::from_secs_f64(
                cfg.eval_every_secs.min(cfg.duration_secs - now).max(0.01),
            ));
        }
        let counts = run.stop_and_join();
        let elapsed = sw.elapsed_secs();
        Ok(AsyncReport {
            killed,
            recorder: rec,
            updates: counts.updates(),
            grad_steps: counts.grad_steps,
            proj_steps: counts.proj_steps,
            conflicts: counts.conflicts,
            messages: counts.messages,
            updates_per_sec: counts.updates() as f64 / elapsed,
            final_params: transport.snapshot(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGen;
    use crate::graph::regular_circulant;

    fn cluster(n: usize, k: usize, seed: u64) -> (AsyncCluster, Dataset) {
        let gen = SyntheticGen::new(n, 10, 4, 2.0, 0.5, 0.3, seed);
        let mut rng = Xoshiro256pp::seeded(seed);
        let shards = (0..n).map(|i| gen.node_dataset(i, 60, &mut rng)).collect();
        let test = gen.global_test_set(200, &mut rng);
        (AsyncCluster::new(regular_circulant(n, k), shards), test)
    }

    #[test]
    fn async_run_makes_progress_without_barriers() {
        let (c, test) = cluster(6, 2, 1);
        let cfg = AsyncConfig {
            duration_secs: 1.2,
            rate_hz: 400.0,
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 200, "updates={}", rep.updates);
        assert!(rep.grad_steps > 0 && rep.proj_steps > 0);
        let last = rep.recorder.last().unwrap();
        assert!(last.test_err < 0.7, "err={}", last.test_err);
        assert!(rep.updates_per_sec > 100.0);
    }

    #[test]
    fn thread_per_node_engine_still_runs() {
        // The baseline engine stays alive (benches and the trace test
        // below compare against it).
        let (c, test) = cluster(6, 2, 1);
        let cfg = AsyncConfig {
            duration_secs: 1.0,
            rate_hz: 400.0,
            engine: EngineKind::ThreadPerNode,
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 150, "updates={}", rep.updates);
        assert!(rep.grad_steps > 0 && rep.proj_steps > 0);
    }

    #[test]
    fn explicit_executor_count_is_honored() {
        // --executors 2 with 8 nodes: 2 executor threads drive 8 tasks.
        let (c, test) = cluster(8, 2, 7);
        let cfg = AsyncConfig {
            duration_secs: 1.0,
            rate_hz: 400.0,
            engine: EngineKind::Executors(2),
            ..AsyncConfig::quick(8)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 150, "updates={}", rep.updates);
        assert!(rep.proj_steps > 0);
    }

    /// Run a fixed ring deterministically on the given engine and
    /// return (params, counts) after exactly `budget` events.
    fn deterministic_trace(engine: EngineKind, budget: u64) -> (Vec<Vec<f32>>, Counts) {
        let n = 8;
        let gen = SyntheticGen::new(n, 10, 4, 2.0, 0.5, 0.3, 42);
        let mut rng = Xoshiro256pp::seeded(42);
        let shards: Vec<Dataset> = (0..n).map(|i| gen.node_dataset(i, 40, &mut rng)).collect();
        let plan = WorkloadPlan::homogeneous(Objective::LogReg, shards);
        let graph = regular_circulant(n, 2);
        let cfg = AsyncConfig {
            engine,
            deterministic_events: Some(budget),
            seed: 42,
            ..AsyncConfig::quick(n)
        };
        let transport: Arc<dyn Transport> = Arc::new(SharedMem::new(n, plan.param_len()));
        let run = spawn_shard(&graph, &plan, &cfg, Arc::clone(&transport), 0..n, None);
        // The engine stops itself once the budget is spent.
        let shared = Arc::clone(&run.shared);
        run.join();
        (transport.snapshot(), shared.counts())
    }

    #[test]
    fn single_executor_reproduces_the_thread_per_node_trace() {
        // The cross-engine equivalence pin: on a fixed ring with a
        // fixed seed, the executor pool (one executor, virtual time)
        // fires the same events in the same order as the serialized
        // thread-per-node engine — the consensus trajectory is
        // bit-identical at every probed horizon, because every wakeup
        // derives from the same per-(seed, node id) RNG stream.
        for budget in [150u64, 400] {
            let (p_pool, c_pool) = deterministic_trace(EngineKind::Executors(1), budget);
            let (p_tpn, c_tpn) = deterministic_trace(EngineKind::ThreadPerNode, budget);
            assert_eq!(c_pool, c_tpn, "counters diverged at budget {budget}");
            assert!(
                c_pool.updates() > 0,
                "trace fired no updates at budget {budget}"
            );
            for (id, (a, b)) in p_pool.iter().zip(&p_tpn).enumerate() {
                let a_bits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let b_bits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    a_bits, b_bits,
                    "node {id} params diverged at budget {budget}"
                );
            }
        }
    }

    #[test]
    fn deterministic_replay_is_reproducible() {
        // Same engine, same seed, twice: identical down to the bits.
        let (p1, c1) = deterministic_trace(EngineKind::Executors(1), 300);
        let (p2, c2) = deterministic_trace(EngineKind::Executors(1), 300);
        assert_eq!(c1, c2);
        assert_eq!(p1, p2);
    }

    #[test]
    fn heterogeneous_rates_still_converge() {
        let (c, test) = cluster(6, 4, 3);
        let cfg = AsyncConfig {
            duration_secs: 1.0,
            rate_hz: 300.0,
            speed_spread: 1.0, // ~3x rate disparity between nodes
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 100);
        // Consensus must still fall (async + stragglers don't break it).
        let first = rep.recorder.records.first().unwrap().consensus;
        let last = rep.recorder.last().unwrap().consensus;
        assert!(last <= first.max(1.0), "consensus {first} -> {last}");
    }

    #[test]
    fn survives_node_failures() {
        // The robustness claim: no server = no single point of failure.
        // Crash 2 of 8 nodes mid-run; the survivors keep updating and
        // still reach a useful model.
        let (c, test) = cluster(8, 4, 9);
        let cfg = AsyncConfig {
            duration_secs: 1.4,
            rate_hz: 400.0,
            kill_after_secs: Some(0.4),
            kill_nodes: 2,
            ..AsyncConfig::quick(8)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert_eq!(rep.killed, 2);
        // Updates continued well past the crash point.
        let at_kill = rep
            .recorder
            .records
            .iter()
            .find(|r| r.time_secs >= 0.4)
            .map(|r| r.grad_steps + r.proj_steps)
            .unwrap_or(0);
        assert!(
            rep.updates > at_kill + 50,
            "no progress after crash: {} vs {}",
            rep.updates,
            at_kill
        );
        // The surviving cohort still improves on random guessing.
        let last = rep.recorder.last().unwrap();
        assert!(last.test_err < 0.7, "err={}", last.test_err);
    }

    #[test]
    fn async_cluster_runs_hinge_objective() {
        // Same runtime, (dim)-shaped SVM parameters.
        let (c, test) = cluster(6, 2, 13);
        let c = c.with_objective(Objective::hinge());
        let cfg = AsyncConfig {
            duration_secs: 0.8,
            rate_hz: 400.0,
            stepsize: Objective::hinge().default_stepsize(6),
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 100, "updates={}", rep.updates);
        // Hinge parameter is (dim) = 10, not (dim × classes).
        assert!(rep.final_params.iter().all(|w| w.len() == 10));
        assert!(rep
            .final_params
            .iter()
            .all(|w| w.iter().all(|v| v.is_finite())));
        // The model moved off the all-zeros init.
        assert!(rep
            .final_params
            .iter()
            .any(|w| w.iter().any(|v| *v != 0.0)));
    }

    #[test]
    fn mixed_objective_plan_runs_heterogeneous_nodes() {
        // Hinge and lasso nodes share the (dim)-shaped parameter space
        // and gossip across family boundaries.
        use crate::workload::PlanSpec;
        let (plan, test) =
            PlanSpec::Mixed { alpha: 0.5 }.build(Objective::LogReg, 6, 60, 200, 17);
        let c = AsyncCluster::from_plan(regular_circulant(6, 2), plan);
        let cfg = AsyncConfig {
            duration_secs: 1.0,
            rate_hz: 400.0,
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 100, "updates={}", rep.updates);
        assert!(rep.proj_steps > 0, "no cross-family projection applied");
        // (dim)-shaped parameters, all finite.
        assert!(rep.final_params.iter().all(|w| w.len() == 50));
        assert!(rep
            .final_params
            .iter()
            .all(|w| w.iter().all(|v| v.is_finite())));
        let last = rep.recorder.last().unwrap();
        assert!(last.test_loss.is_finite() && last.test_err.is_finite());
    }

    #[test]
    fn lockup_conflicts_are_counted_under_contention() {
        // Dense graph + high rate = lots of neighborhood contention.
        let (c, test) = cluster(8, 6, 5);
        let cfg = AsyncConfig {
            duration_secs: 0.8,
            rate_hz: 2000.0,
            gossip_hold_secs: 0.002, // hold locks across a simulated RTT
            ..AsyncConfig::quick(8)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(
            rep.conflicts > 0,
            "expected lock-up conflicts under contention"
        );
        assert!(rep.messages > 0);
    }

    #[test]
    fn channel_transport_reaches_the_same_kind_of_model() {
        // The message-passing substrate: slower rounds (protocol + poll
        // cadence) but the same algorithm; the run must apply updates,
        // complete projections, and keep every vector finite.
        let (c, test) = cluster(6, 2, 21);
        let cfg = AsyncConfig {
            duration_secs: 1.5,
            rate_hz: 400.0,
            transport: TransportKind::Channel,
            ..AsyncConfig::quick(6)
        };
        let rep = c.run(&cfg, &test).unwrap();
        assert!(rep.updates > 50, "updates={}", rep.updates);
        assert!(rep.grad_steps > 0);
        assert!(
            rep.proj_steps > 0,
            "no projection round completed over the channel transport"
        );
        assert!(rep
            .final_params
            .iter()
            .all(|w| w.iter().all(|v| v.is_finite())));
    }
}
