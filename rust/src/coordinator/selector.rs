//! Node selection (§IV-A) and conflict handling (§IV-C).
//!
//! Two mechanisms:
//!
//! * [`CentralSelector`] — the idealized uniform (or weighted) pick the
//!   paper's analysis assumes. One node per slot, no conflicts.
//! * [`GeometricSelector`] — the fully distributed §IV-A design: every
//!   node independently draws a Geometric(p) countdown and "self-selects"
//!   on reaching zero. Several nodes can fire in the same slot; whether
//!   adjacent firings are serialized (lock-up) or applied anyway is the
//!   §IV-C [`ConflictPolicy`](super::config::ConflictPolicy) decision
//!   made by the trainer.

use crate::util::rng::Xoshiro256pp;

/// The outcome of one selection slot.
#[derive(Clone, Debug, PartialEq)]
pub struct Slot {
    /// Nodes that fired this slot (central: exactly one).
    pub fired: Vec<usize>,
    /// Empty slots skipped to reach this firing (distributed mode).
    pub idle_slots: u64,
}

/// Uniform or weighted central selection — requires a coordinator in
/// practice; the paper uses it for analysis and simulation.
#[derive(Clone, Debug)]
pub struct CentralSelector {
    n: usize,
    weights: Option<Vec<f64>>,
}

impl CentralSelector {
    pub fn uniform(n: usize) -> Self {
        assert!(n > 0);
        Self { n, weights: None }
    }

    /// Non-uniform selection (§IV-A notes the geometric parameters can be
    /// tuned per node; this is the central equivalent).
    pub fn weighted(weights: Vec<f64>) -> Self {
        assert!(!weights.is_empty());
        assert!(weights.iter().all(|&w| w >= 0.0));
        assert!(weights.iter().sum::<f64>() > 0.0);
        Self {
            n: weights.len(),
            weights: Some(weights),
        }
    }

    pub fn next(&mut self, rng: &mut Xoshiro256pp) -> Slot {
        let node = match &self.weights {
            None => rng.index(self.n),
            Some(w) => rng.weighted_index(w),
        };
        Slot {
            fired: vec![node],
            idle_slots: 0,
        }
    }
}

/// Distributed geometric-countdown selection (§IV-A).
///
/// Every node keeps an independent countdown sampled from Geometric(p_i).
/// Each global slot decrements all countdowns; nodes at zero fire and
/// redraw. No controller is involved — in a real deployment each node
/// just sleeps for its own countdown. Simultaneous firings (ties) are
/// returned together; the §IV-C conflict policy decides what happens to
/// adjacent ones.
#[derive(Clone, Debug)]
pub struct GeometricSelector {
    /// Remaining slots until each node fires.
    countdown: Vec<u64>,
    /// Per-node firing probability per slot.
    p: Vec<f64>,
    /// Per-node RNG streams — a node only uses local randomness.
    rngs: Vec<Xoshiro256pp>,
}

impl GeometricSelector {
    pub fn uniform(n: usize, p: f64, seed: u64) -> Self {
        Self::with_rates(vec![p; n], seed)
    }

    /// Per-node rates: node i fires with probability p_i each slot, so
    /// selection frequency is proportional to p_i (the §IV-A "carefully
    /// design the parameter ... so that the probability for different
    /// nodes to be selected is preferred").
    pub fn with_rates(p: Vec<f64>, seed: u64) -> Self {
        assert!(!p.is_empty());
        assert!(p.iter().all(|&x| x > 0.0 && x <= 1.0));
        let mut root = Xoshiro256pp::seeded(seed);
        let mut rngs: Vec<Xoshiro256pp> =
            (0..p.len()).map(|i| root.split(i as u64)).collect();
        let countdown = p
            .iter()
            .zip(rngs.iter_mut())
            .map(|(&pi, rng)| rng.geometric(pi))
            .collect();
        Self { countdown, p, rngs }
    }

    /// Advance to the next slot in which at least one node fires.
    pub fn next(&mut self) -> Slot {
        // Jump directly to the minimum countdown (equivalent to ticking
        // slot by slot, without the O(idle) cost).
        let min = *self.countdown.iter().min().unwrap();
        let mut fired = Vec::new();
        for (i, c) in self.countdown.iter_mut().enumerate() {
            *c -= min;
            if *c == 0 {
                fired.push(i);
                *c = self.rngs[i].geometric(self.p[i]);
            }
        }
        debug_assert!(!fired.is_empty());
        Slot {
            fired,
            idle_slots: min - 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn central_uniform_covers_all_nodes() {
        let mut sel = CentralSelector::uniform(10);
        let mut rng = Xoshiro256pp::seeded(0);
        let mut counts = vec![0usize; 10];
        for _ in 0..20_000 {
            let s = sel.next(&mut rng);
            assert_eq!(s.fired.len(), 1);
            counts[s.fired[0]] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 2000.0).abs() < 300.0, "{counts:?}");
        }
    }

    #[test]
    fn central_weighted_prefers_heavy_nodes() {
        let mut sel = CentralSelector::weighted(vec![1.0, 3.0]);
        let mut rng = Xoshiro256pp::seeded(1);
        let mut c = [0usize; 2];
        for _ in 0..40_000 {
            c[sel.next(&mut rng).fired[0]] += 1;
        }
        let ratio = c[1] as f64 / c[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn geometric_uniform_rates_select_uniformly() {
        let mut sel = GeometricSelector::uniform(8, 0.05, 3);
        let mut counts = vec![0usize; 8];
        for _ in 0..40_000 {
            for i in sel.next().fired {
                counts[i] += 1;
            }
        }
        let total: usize = counts.iter().sum();
        let expect = total as f64 / 8.0;
        for &c in &counts {
            assert!(
                (c as f64 - expect).abs() < expect * 0.1,
                "counts={counts:?}"
            );
        }
    }

    #[test]
    fn geometric_rates_shape_selection_frequency() {
        let mut sel = GeometricSelector::with_rates(vec![0.02, 0.08], 5);
        let mut counts = [0usize; 2];
        for _ in 0..30_000 {
            for i in sel.next().fired {
                counts[i] += 1;
            }
        }
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((ratio - 4.0).abs() < 0.5, "ratio={ratio}");
    }

    #[test]
    fn geometric_produces_ties() {
        // With high per-slot rates, simultaneous firings must occur —
        // that's the §IV-C conflict scenario.
        let mut sel = GeometricSelector::uniform(20, 0.3, 7);
        let mut ties = 0;
        for _ in 0..2000 {
            if sel.next().fired.len() > 1 {
                ties += 1;
            }
        }
        assert!(ties > 100, "expected frequent ties, got {ties}");
    }

    #[test]
    fn geometric_idle_slots_accounted() {
        // With tiny rates, firings are sparse: idle slots dominate.
        let mut sel = GeometricSelector::uniform(2, 0.001, 11);
        let mut idle = 0u64;
        let mut fired = 0u64;
        for _ in 0..200 {
            let s = sel.next();
            idle += s.idle_slots;
            fired += s.fired.len() as u64;
        }
        // E[slots per firing] ≈ 1/(n·p) = 500.
        let per = idle as f64 / fired as f64;
        assert!(per > 100.0, "idle per firing = {per}");
    }
}
