//! # dasgd — Fully Distributed and Asynchronized SGD for Networked Systems
//!
//! A three-layer (rust + JAX + Pallas, AOT via PJRT) reproduction of
//! Ying Zhang, *"Fully Distributed and Asynchronized Stochastic Gradient
//! Descent for Networked Systems"* (2017).
//!
//! Layer 3 (this crate) is the coordination system: the Alg. 2 trainer
//! (random gradient steps + random neighborhood-projection steps), the
//! §IV distributed node-selection / lock-up protocols, a threaded
//! asynchronous actor runtime, a discrete-event straggler simulator, and
//! the baselines the paper positions itself against. The per-node
//! algorithm lives once, in [`node_logic`], and runs over pluggable
//! [`transport`] substrates (shared memory, message passing, the
//! delay/drop/partition-aware virtual-time network, or [`net`]'s
//! multi-process TCP deployment). Layers 2/1 (JAX
//! model + Pallas kernels) are AOT-lowered to HLO text in `artifacts/`
//! and executed through [`runtime`]; python never runs on the training
//! path.
//!
//! See DESIGN.md for the full inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod graph;
pub mod linalg;
pub mod membership;
pub mod metrics;
pub mod model;
pub mod net;
pub mod node_logic;
pub mod objective;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod transport;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
