//! Hand-rolled CLI argument parser (`clap` does not resolve offline).
//!
//! Supports `binary <command> [--flag value] [--switch]` with typed
//! accessors, defaults, and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err("empty flag name".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(String::as_str)
    }

    pub fn get_f64(&self, flag: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{flag} {v:?}: {e}")),
        }
    }

    pub fn get_u64(&self, flag: &str, default: u64) -> Result<u64, String> {
        match self.flags.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{flag} {v:?}: {e}")),
        }
    }

    pub fn get_usize(&self, flag: &str, default: usize) -> Result<usize, String> {
        self.get_u64(flag, default as u64).map(|v| v as usize)
    }

    pub fn get_str<'a>(&'a self, flag: &str, default: &'a str) -> &'a str {
        self.get(flag).unwrap_or(default)
    }

    /// Flags the caller never read — typo detection.
    pub fn unknown_flags(&self, known: &[&str]) -> Vec<String> {
        self.flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !known.contains(&k.as_str()))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_flags_switches() {
        let a = parse("fig2 extra --scale 0.5 --seed=7 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig2"));
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("train");
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 1.0);
        assert_eq!(a.get_str("backend", "native"), "native");
        let bad = parse("x --scale abc");
        assert!(bad.get_f64("scale", 1.0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("cmd --good 1 --bad 2 --flag3");
        let unknown = a.unknown_flags(&["good", "flag3"]);
        assert_eq!(unknown, vec!["bad".to_string()]);
    }

    #[test]
    fn flag_followed_by_flag_is_switch() {
        let a = parse("cmd --quiet --scale 2.0");
        assert!(a.has("quiet"));
        assert_eq!(a.get_f64("scale", 0.0).unwrap(), 2.0);
    }
}
