//! Hand-rolled CLI argument parser (`clap` does not resolve offline).
//!
//! Supports `binary <command> [--flag value] [--switch]` with typed
//! accessors, defaults, and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if name.is_empty() {
                    return Err("empty flag name".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(String::as_str)
    }

    pub fn get_f64(&self, flag: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{flag} {v:?}: {e}")),
        }
    }

    pub fn get_u64(&self, flag: &str, default: u64) -> Result<u64, String> {
        match self.flags.get(flag) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| format!("--{flag} {v:?}: {e}")),
        }
    }

    pub fn get_usize(&self, flag: &str, default: usize) -> Result<usize, String> {
        self.get_u64(flag, default as u64).map(|v| v as usize)
    }

    pub fn get_str<'a>(&'a self, flag: &str, default: &'a str) -> &'a str {
        self.get(flag).unwrap_or(default)
    }

    /// Flags the caller never read — typo detection.
    pub fn unknown_flags(&self, known: &[&str]) -> Vec<String> {
        self.flags
            .keys()
            .chain(self.switches.iter())
            .filter(|k| !known.contains(&k.as_str()))
            .cloned()
            .collect()
    }

    /// Error out on any flag/switch not in `known`, suggesting the
    /// closest known flag ("did you mean …?") when one is plausibly a
    /// typo. Commands call this after reading their flags so that
    /// misspellings fail loudly instead of silently using defaults.
    pub fn reject_unknown(&self, known: &[&str]) -> Result<(), String> {
        let unknown = self.unknown_flags(known);
        let Some(first) = unknown.first() else {
            return Ok(());
        };
        let mut msg = format!("unknown flag --{first}");
        if let Some(best) = closest(first, known) {
            msg.push_str(&format!(" (did you mean --{best}?)"));
        }
        if unknown.len() > 1 {
            msg.push_str(&format!(
                "; also unknown: {}",
                unknown[1..]
                    .iter()
                    .map(|f| format!("--{f}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ));
        }
        msg.push_str(&format!(
            ". Known flags: {}",
            known
                .iter()
                .map(|f| format!("--{f}"))
                .collect::<Vec<_>>()
                .join(" ")
        ));
        Err(msg)
    }

    /// Error out when a flag that requires a value was passed bare —
    /// `--objective` at the end of the line (or followed by another
    /// `--flag`) parses as a switch and would otherwise silently fall
    /// back to its default.
    pub fn require_values(&self, value_flags: &[&str]) -> Result<(), String> {
        match self
            .switches
            .iter()
            .find(|s| value_flags.contains(&s.as_str()))
        {
            Some(f) => Err(format!("--{f} requires a value")),
            None => Ok(()),
        }
    }
}

/// "Did you mean …?" helper for flag *values* (`--transport chanel`),
/// not just flag names: the `known` candidate closest to `input` in
/// edit distance, if it plausibly is a typo. Commands use this to
/// decorate unknown-value errors the same way [`Args::reject_unknown`]
/// decorates unknown flags.
pub fn did_you_mean<'a>(input: &str, known: &[&'a str]) -> Option<&'a str> {
    closest(input, known)
}

/// The `known` candidate closest to `flag` in edit distance, if it is
/// close enough to look like a typo (distance ≤ 2, or ≤ 1 for very
/// short flags).
fn closest<'a>(flag: &str, known: &[&'a str]) -> Option<&'a str> {
    let max_dist = if flag.len() <= 3 { 1 } else { 2 };
    known
        .iter()
        .map(|&k| (levenshtein(flag, k), k))
        .filter(|&(d, _)| d <= max_dist)
        .min_by_key(|&(d, _)| d)
        .map(|(_, k)| k)
}

/// Classic two-row Levenshtein distance (flags are short; O(nm) is fine).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn command_flags_switches() {
        let a = parse("fig2 extra --scale 0.5 --seed=7 --verbose");
        assert_eq!(a.command.as_deref(), Some("fig2"));
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra".to_string()]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("train");
        assert_eq!(a.get_f64("scale", 1.0).unwrap(), 1.0);
        assert_eq!(a.get_str("backend", "native"), "native");
        let bad = parse("x --scale abc");
        assert!(bad.get_f64("scale", 1.0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("cmd --good 1 --bad 2 --flag3");
        let unknown = a.unknown_flags(&["good", "flag3"]);
        assert_eq!(unknown, vec!["bad".to_string()]);
    }

    #[test]
    fn levenshtein_distances() {
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("seed", "seed"), 0);
        assert_eq!(levenshtein("sede", "seed"), 2); // transposition = 2 edits
        assert_eq!(levenshtein("objectiv", "objective"), 1);
    }

    #[test]
    fn reject_unknown_suggests_closest() {
        let a = parse("train --objectve hinge --seed 3");
        let err = a
            .reject_unknown(&["objective", "seed", "scale"])
            .unwrap_err();
        assert!(err.contains("--objectve"), "{err}");
        assert!(err.contains("did you mean --objective?"), "{err}");

        // Exact flags pass.
        let ok = parse("train --objective hinge --seed 3");
        assert!(ok.reject_unknown(&["objective", "seed"]).is_ok());

        // Distant junk gets no bogus suggestion but still errors.
        let junk = parse("train --zzzzzz 1");
        let err = junk.reject_unknown(&["objective", "seed"]).unwrap_err();
        assert!(!err.contains("did you mean"), "{err}");
        assert!(err.contains("Known flags"), "{err}");

        // Switches are validated too.
        let sw = parse("cmd --verbos");
        let err = sw.reject_unknown(&["verbose"]).unwrap_err();
        assert!(err.contains("did you mean --verbose?"), "{err}");
    }

    #[test]
    fn did_you_mean_values() {
        let kinds = ["shared", "channel", "socket"];
        assert_eq!(did_you_mean("chanel", &kinds), Some("channel"));
        assert_eq!(did_you_mean("socke", &kinds), Some("socket"));
        assert_eq!(did_you_mean("zmq", &kinds), None);
    }

    #[test]
    fn require_values_catches_bare_value_flags() {
        // Value forgotten at end of line → parsed as a switch.
        let a = parse("train --objective");
        let err = a.require_values(&["objective", "seed"]).unwrap_err();
        assert!(err.contains("--objective requires a value"), "{err}");
        // Value forgotten before another flag.
        let b = parse("train --objective --seed 3");
        assert!(b.require_values(&["objective", "seed"]).is_err());
        // Properly valued flags pass.
        let ok = parse("train --objective hinge --seed 3");
        assert!(ok.require_values(&["objective", "seed"]).is_ok());
        // Genuine boolean switches are unaffected when not listed.
        let sw = parse("cmd --verbose");
        assert!(sw.require_values(&["seed"]).is_ok());
    }

    #[test]
    fn flag_followed_by_flag_is_switch() {
        let a = parse("cmd --quiet --scale 2.0");
        assert!(a.has("quiet"));
        assert_eq!(a.get_f64("scale", 0.0).unwrap(), 2.0);
    }
}
