//! Workload assignment: *who* optimizes *what*, on *which* data.
//!
//! The paper sells Alg. 2 for "a very large and heterogeneous system",
//! yet until this subsystem every engine constructed identical nodes:
//! one global objective and IID-by-construction synthetic shards
//! rebuilt from the seed wherever a process needed them. A
//! [`WorkloadPlan`] makes heterogeneity first-class — it maps each node
//! to a [`NodeAssignment`] (a §II objective plus a data shard) and is
//! the single world-construction input of every engine
//! ([`spawn_shard`](crate::coordinator::spawn_shard), the
//! [`SimNet`](crate::transport::SimNet) driver, and the baselines'
//! plan variants).
//!
//! # Non-IID partitioners
//!
//! A plan's data can come from the historical §V-A per-node generator
//! ([`PlanSpec::Synth`]) or from a *global* base dataset split by one
//! of three skew families (the standard federated-heterogeneity
//! recipes; see Bedi et al., arXiv:1707.05816, and R-FAST,
//! arXiv:2307.11617 for the optimization setting they model):
//!
//! * **label skew** ([`partition_label_skew`]) — per class, node
//!   proportions are drawn from `Dirichlet(α)`; small α concentrates a
//!   class on few nodes (α → ∞ recovers IID);
//! * **quantity skew** ([`partition_quantity_skew`]) — shard *sizes*
//!   are `Dirichlet(α)`-distributed while content stays IID;
//! * **feature shift** ([`feature_shift`]) — IID rows, but each node
//!   observes them through its own additive per-feature offset
//!   (covariate shift).
//!
//! Every partitioner assigns **each base row to exactly one node** and
//! leaves no node empty (pinned by the property tests in
//! `rust/tests/it_workload.rs`). Partitioners are generic over the base
//! [`Dataset`] — synthetic, notMNIST, or anything else.
//!
//! # Mixed objectives
//!
//! Nodes may disagree on loss family as long as they agree on the
//! *parameter space*: Eq. (7) averages neighbors' flat vectors, so a
//! plan asserts all assignments share `param_len`. Hinge and Lasso are
//! both `(dim)`-shaped and mix freely ([`PlanSpec::Mixed`]); LogReg's
//! `(dim × classes)` matrix cannot mix with them. Evaluation of a mixed
//! cohort follows one convention, implemented by
//! [`Probe::mixed`](crate::node_logic::Probe::mixed): the mean
//! parameter is evaluated under every family present and the reported
//! `(loss, err)` is the node-count-weighted average of the per-family
//! metrics (consensus needs no rule — it lives in the shared parameter
//! space). Mixed plans also give each node its own family's default
//! stepsize; a single global schedule that is stable for hinge would
//! overstep the Lasso curvature bound.
//!
//! Plans built from `(spec, nodes, seed)` are bit-deterministic, and
//! assignments serialize through the wire codec
//! ([`WireMsg::PlanAssign`](crate::net::wire::WireMsg)) so `dasgd
//! launch` ships real shards to worker processes instead of having
//! them regenerate the world. See docs/heterogeneity.md.

use crate::data::Dataset;
use crate::node_logic::StrategyKind;
use crate::objective::Objective;
use crate::util::rng::Xoshiro256pp;

/// One node's workload: the loss family it optimizes, the local data
/// shard it draws gradients from, and the update [`StrategyKind`] it
/// runs (see docs/algorithms.md — strategies may differ per node).
#[derive(Clone, Debug)]
pub struct NodeAssignment {
    pub objective: Objective,
    pub shard: Dataset,
    pub strategy: StrategyKind,
}

impl NodeAssignment {
    /// An assignment running the paper-baseline [`StrategyKind::Dasgd`]
    /// update rule (every legacy entry point).
    pub fn new(objective: Objective, shard: Dataset) -> Self {
        Self {
            objective,
            shard,
            strategy: StrategyKind::Dasgd,
        }
    }
}

/// The full system workload: one [`NodeAssignment`] per node, validated
/// so that every engine can rely on a single flat parameter length and
/// one `(dim, classes)` data shape.
#[derive(Clone, Debug)]
pub struct WorkloadPlan {
    nodes: Vec<NodeAssignment>,
    dim: usize,
    classes: usize,
    param_len: usize,
    /// Whether the *deployment-wide* plan mixes loss families. Usually
    /// derived from `nodes`; a worker's partial view can carry the
    /// authoritative value shipped by the launcher (its own slice may
    /// look homogeneous even when the system is mixed).
    mixed: bool,
}

impl WorkloadPlan {
    /// Validate and wrap per-node assignments. Panics when shards
    /// disagree on `(dim, classes)`, when objectives disagree on
    /// parameter length (LogReg cannot mix with hinge/Lasso), or when
    /// no node has any data.
    pub fn new(nodes: Vec<NodeAssignment>) -> Self {
        assert!(!nodes.is_empty(), "a plan needs at least one node");
        let shape = nodes
            .iter()
            .find(|a| !a.shard.is_empty())
            .map(|a| (a.shard.dim(), a.shard.classes()))
            .expect("a plan needs at least one non-empty shard");
        Self::with_shape(nodes, shape.0, shape.1)
    }

    /// [`WorkloadPlan::new`] with an explicit data shape, so plans with
    /// placeholder (empty) shards — a worker's view of nodes it does
    /// not own — validate against the deployment's real shape.
    pub fn with_shape(nodes: Vec<NodeAssignment>, dim: usize, classes: usize) -> Self {
        assert!(!nodes.is_empty(), "a plan needs at least one node");
        let param_len = nodes[0].objective.param_len(dim, classes);
        for (i, a) in nodes.iter().enumerate() {
            if !a.shard.is_empty() {
                assert_eq!(
                    (a.shard.dim(), a.shard.classes()),
                    (dim, classes),
                    "node {i}'s shard disagrees on the data shape"
                );
            }
            assert_eq!(
                a.objective.param_len(dim, classes),
                param_len,
                "node {i} optimizes {} whose parameter length differs from node 0's {} \
                 — gossip averages flat vectors, so a plan cannot mix logreg with \
                 the (dim)-shaped families",
                a.objective,
                nodes[0].objective
            );
        }
        let mixed = census(&nodes).len() > 1;
        Self {
            nodes,
            dim,
            classes,
            param_len,
            mixed,
        }
    }

    /// The homogeneous special case every legacy entry point builds:
    /// one objective, one shard per node.
    pub fn homogeneous(objective: Objective, shards: Vec<Dataset>) -> Self {
        Self::new(
            shards
                .into_iter()
                .map(|shard| NodeAssignment::new(objective, shard))
                .collect(),
        )
    }

    /// A worker's partial view: assignments for the nodes it was
    /// shipped, placeholders (empty shards, the first real objective)
    /// everywhere else. Errors instead of panicking — the input crossed
    /// a process boundary.
    ///
    /// `global_mixed` is the launcher's authoritative verdict on
    /// whether the *whole* deployment mixes loss families (shipped in
    /// `PlanStart`): a worker owning a single node of a mixed plan
    /// would otherwise see a homogeneous slice and drop the per-family
    /// stepsize policy its node relies on.
    pub fn from_partial(
        n: usize,
        dim: usize,
        classes: usize,
        assigned: Vec<(usize, NodeAssignment)>,
        global_mixed: bool,
    ) -> anyhow::Result<Self> {
        let Some(fill) = assigned.first().map(|(_, a)| a.objective) else {
            anyhow::bail!("a partial plan needs at least one assignment");
        };
        let mut slots: Vec<Option<NodeAssignment>> = (0..n).map(|_| None).collect();
        for (id, a) in assigned {
            if id >= n {
                anyhow::bail!("assignment for node {id} outside 0..{n}");
            }
            if (a.shard.dim(), a.shard.classes()) != (dim, classes) {
                anyhow::bail!(
                    "node {id}'s shipped shard is {}x{} (expected {dim}x{classes})",
                    a.shard.dim(),
                    a.shard.classes()
                );
            }
            if a.objective.param_len(dim, classes) != fill.param_len(dim, classes) {
                anyhow::bail!("node {id}'s objective disagrees on parameter length");
            }
            if slots[id].replace(a).is_some() {
                anyhow::bail!("node {id} assigned twice");
            }
        }
        let nodes = slots
            .into_iter()
            .map(|s| s.unwrap_or_else(|| NodeAssignment::new(fill, Dataset::new(dim, classes))))
            .collect();
        let mut plan = Self::with_shape(nodes, dim, classes);
        plan.mixed = plan.mixed || global_mixed;
        Ok(plan)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// The flat parameter length every node's β_i shares.
    pub fn param_len(&self) -> usize {
        self.param_len
    }

    pub fn node(&self, i: usize) -> &NodeAssignment {
        &self.nodes[i]
    }

    pub fn objective(&self, i: usize) -> Objective {
        self.nodes[i].objective
    }

    /// The update strategy node `i` runs (paper-baseline `dasgd`
    /// unless the plan says otherwise).
    pub fn strategy(&self, i: usize) -> StrategyKind {
        self.nodes[i].strategy
    }

    /// Do nodes disagree on update strategy?
    pub fn mixed_strategies(&self) -> bool {
        self.nodes
            .iter()
            .any(|a| a.strategy != self.nodes[0].strategy)
    }

    pub fn shard(&self, i: usize) -> &Dataset {
        &self.nodes[i].shard
    }

    /// Every node's objective, in node order (the input of
    /// [`Probe::mixed`](crate::node_logic::Probe::mixed)).
    pub fn objectives(&self) -> Vec<Objective> {
        self.nodes.iter().map(|a| a.objective).collect()
    }

    /// Loss-family census: one entry per distinct family, with its node
    /// count, in first-appearance order.
    pub fn families(&self) -> Vec<(Objective, usize)> {
        census(&self.nodes)
    }

    /// Do nodes disagree on loss family? For a worker's partial plan
    /// this reflects the *deployment-wide* answer (see
    /// [`WorkloadPlan::from_partial`]), not just the local slice.
    pub fn is_mixed(&self) -> bool {
        self.mixed
    }

    /// The same plan with every node switched to `objective`
    /// (re-validated — the parameter length may change; per-node
    /// strategies are preserved).
    pub fn with_uniform_objective(self, objective: Objective) -> Self {
        let (dim, classes) = (self.dim, self.classes);
        Self::with_shape(
            self.nodes
                .into_iter()
                .map(|a| NodeAssignment {
                    objective,
                    shard: a.shard,
                    strategy: a.strategy,
                })
                .collect(),
            dim,
            classes,
        )
    }

    /// The same plan with every node switched to `strategy`. No
    /// re-validation — the strategy does not touch the parameter
    /// space, only the update rule.
    pub fn with_uniform_strategy(mut self, strategy: StrategyKind) -> Self {
        for a in &mut self.nodes {
            a.strategy = strategy;
        }
        self
    }

    /// The same plan with node `i` switched to `strategy` (mixed-
    /// strategy deployments: chaos drills, A/B cohorts).
    pub fn with_node_strategy(mut self, i: usize, strategy: StrategyKind) -> Self {
        self.nodes[i].strategy = strategy;
        self
    }
}

/// Family census over raw assignments (grouped by family *name*; λ
/// does not split a family — it changes the loss value, not the
/// parameter shape or stepsize class).
fn census(nodes: &[NodeAssignment]) -> Vec<(Objective, usize)> {
    let mut out: Vec<(Objective, usize)> = Vec::new();
    for a in nodes {
        match out.iter_mut().find(|(o, _)| o.name() == a.objective.name()) {
            Some((_, c)) => *c += 1,
            None => out.push((a.objective, 1)),
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Dirichlet sampling
// ---------------------------------------------------------------------------

/// One Gamma(shape, 1) draw (Marsaglia–Tsang, with the α < 1 boost).
fn gamma(rng: &mut Xoshiro256pp, shape: f64) -> f64 {
    debug_assert!(shape > 0.0);
    if shape < 1.0 {
        // Boost: G(α) = G(α+1) · U^{1/α}.
        let u = positive_uniform(rng);
        return gamma(rng, shape + 1.0) * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.next_gauss();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = positive_uniform(rng);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

fn positive_uniform(rng: &mut Xoshiro256pp) -> f64 {
    loop {
        let u = rng.next_f64();
        if u > 0.0 {
            return u;
        }
    }
}

/// One `Dirichlet(α, …, α)` draw over `k` parts. Tiny α can underflow
/// every Gamma draw to zero; that degenerate case collapses to a
/// one-hot (the distribution's own α → 0 limit).
pub fn dirichlet(rng: &mut Xoshiro256pp, alpha: f64, k: usize) -> Vec<f64> {
    assert!(k > 0 && alpha > 0.0);
    let draws: Vec<f64> = (0..k).map(|_| gamma(rng, alpha)).collect();
    let total: f64 = draws.iter().sum();
    if total <= 0.0 || !total.is_finite() {
        let mut one_hot = vec![0.0; k];
        one_hot[rng.index(k)] = 1.0;
        return one_hot;
    }
    draws.into_iter().map(|g| g / total).collect()
}

/// Split `total` items over parts proportionally to `props` (largest
/// remainder, ties by index), so counts sum to exactly `total`.
fn apportion(total: usize, props: &[f64]) -> Vec<usize> {
    let mut counts: Vec<usize> = props.iter().map(|p| (p * total as f64) as usize).collect();
    let assigned: usize = counts.iter().sum();
    let mut order: Vec<usize> = (0..props.len()).collect();
    order.sort_by(|&a, &b| {
        let fa = props[a] * total as f64 - counts[a] as f64;
        let fb = props[b] * total as f64 - counts[b] as f64;
        fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal)
    });
    for i in 0..total.saturating_sub(assigned) {
        counts[order[i % order.len()]] += 1;
    }
    counts
}

/// Move one row out of the largest part into every empty part, so no
/// node ends up with nothing to train on. Requires `rows ≥ parts`.
fn rebalance_nonempty(parts: &mut [Vec<usize>]) {
    for empty in 0..parts.len() {
        if !parts[empty].is_empty() {
            continue;
        }
        let donor = (0..parts.len())
            .max_by_key(|&i| parts[i].len())
            .expect("at least one part");
        assert!(parts[donor].len() > 1, "fewer rows than nodes");
        let row = parts[donor].pop().expect("donor has rows");
        parts[empty].push(row);
    }
}

// ---------------------------------------------------------------------------
// Partitioners (each base row lands on exactly one node)
// ---------------------------------------------------------------------------

/// IID reference: shuffled round-robin split of `rows` over `nodes`.
pub fn partition_iid(rows: usize, nodes: usize, rng: &mut Xoshiro256pp) -> Vec<Vec<usize>> {
    assert!(nodes > 0 && rows >= nodes, "need at least one row per node");
    let mut idx: Vec<usize> = (0..rows).collect();
    rng.shuffle(&mut idx);
    let mut out = vec![Vec::new(); nodes];
    for (pos, i) in idx.into_iter().enumerate() {
        out[pos % nodes].push(i);
    }
    out
}

/// Label skew: for each class, node proportions are `Dirichlet(α)`;
/// small α gives each class to few nodes.
pub fn partition_label_skew(
    labels: &[usize],
    classes: usize,
    nodes: usize,
    alpha: f64,
    rng: &mut Xoshiro256pp,
) -> Vec<Vec<usize>> {
    assert!(nodes > 0 && labels.len() >= nodes, "need at least one row per node");
    let mut out = vec![Vec::new(); nodes];
    for class in 0..classes {
        let mut rows_c: Vec<usize> = labels
            .iter()
            .enumerate()
            .filter(|(_, &l)| l == class)
            .map(|(i, _)| i)
            .collect();
        if rows_c.is_empty() {
            continue;
        }
        rng.shuffle(&mut rows_c);
        let props = dirichlet(rng, alpha, nodes);
        let counts = apportion(rows_c.len(), &props);
        let mut it = rows_c.into_iter();
        for (node, &count) in counts.iter().enumerate() {
            out[node].extend(it.by_ref().take(count));
        }
    }
    rebalance_nonempty(&mut out);
    out
}

/// Quantity skew: shard sizes are `Dirichlet(α)`-proportioned, content
/// stays IID (shuffled before slicing).
pub fn partition_quantity_skew(
    rows: usize,
    nodes: usize,
    alpha: f64,
    rng: &mut Xoshiro256pp,
) -> Vec<Vec<usize>> {
    assert!(nodes > 0 && rows >= nodes, "need at least one row per node");
    let mut idx: Vec<usize> = (0..rows).collect();
    rng.shuffle(&mut idx);
    let props = dirichlet(rng, alpha, nodes);
    let counts = apportion(rows, &props);
    let mut out = vec![Vec::new(); nodes];
    let mut it = idx.into_iter();
    for (node, &count) in counts.iter().enumerate() {
        out[node].extend(it.by_ref().take(count));
    }
    rebalance_nonempty(&mut out);
    out
}

/// Covariate shift: a copy of `shard` where every row is seen through
/// the node's own additive per-feature offset `N(0, σ)` (labels and
/// row identity untouched).
pub fn feature_shift(shard: &Dataset, sigma: f32, rng: &mut Xoshiro256pp) -> Dataset {
    let dim = shard.dim();
    let offset: Vec<f32> = (0..dim).map(|_| rng.gauss_f32(0.0, sigma)).collect();
    let mut out = Dataset::with_capacity(dim, shard.classes(), shard.len());
    let mut row = vec![0.0f32; dim];
    for i in 0..shard.len() {
        let s = shard.sample(i);
        for (d, v) in row.iter_mut().enumerate() {
            *v = s.features[d] + offset[d];
        }
        out.push(&row, s.label);
    }
    out
}

// ---------------------------------------------------------------------------
// Plan recipes
// ---------------------------------------------------------------------------

/// A named workload recipe — the CLI's `--plan` vocabulary. The skew
/// knob (`--dirichlet-alpha`) is the Dirichlet α for
/// `dirichlet`/`quantity`/`mixed`; `feature-shift` takes its offset σ
/// from the dedicated `--shift-sigma` flag (with `--dirichlet-alpha`
/// as the documented legacy fallback — see [`PlanSpec::parse_spec`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlanSpec {
    /// The historical §V-A world: every node draws from its own
    /// generator distribution, one global objective.
    Synth,
    /// Label-skew Dirichlet split of a pooled global dataset.
    Dirichlet { alpha: f64 },
    /// Quantity-skew split (unequal shard sizes, IID content).
    Quantity { alpha: f64 },
    /// IID split + per-node additive feature offsets of scale σ.
    FeatureShift { sigma: f64 },
    /// Label-skew Dirichlet split *and* a hinge/Lasso objective mix
    /// (alternating by node parity; both are `(dim)`-shaped).
    Mixed { alpha: f64 },
}

impl PlanSpec {
    /// CLI-selectable names (usage strings / did-you-mean).
    pub const NAMES: [&'static str; 5] =
        ["synth", "dirichlet", "quantity", "feature-shift", "mixed"];

    /// Default skew knob (α, or σ for `feature-shift`).
    pub const DEFAULT_ALPHA: f64 = 0.5;

    /// Parse a CLI name with both skew knobs. `sigma` is the dedicated
    /// feature-shift offset scale (`--shift-sigma`); when `None` the
    /// historical fallback applies and `alpha` doubles as σ — kept so
    /// pre-flag invocations (`--plan feature-shift --dirichlet-alpha
    /// 1.0`) reproduce their old worlds bit-for-bit.
    pub fn parse_spec(name: &str, alpha: f64, sigma: Option<f64>) -> Option<Self> {
        match name {
            "synth" => Some(PlanSpec::Synth),
            "dirichlet" => Some(PlanSpec::Dirichlet { alpha }),
            "quantity" => Some(PlanSpec::Quantity { alpha }),
            "feature-shift" => Some(PlanSpec::FeatureShift {
                sigma: sigma.unwrap_or(alpha),
            }),
            "mixed" => Some(PlanSpec::Mixed { alpha }),
            _ => None,
        }
    }

    /// Parse a CLI name with the single legacy skew knob (α, doubling
    /// as σ for `feature-shift`).
    pub fn parse(name: &str, alpha: f64) -> Option<Self> {
        Self::parse_spec(name, alpha, None)
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlanSpec::Synth => "synth",
            PlanSpec::Dirichlet { .. } => "dirichlet",
            PlanSpec::Quantity { .. } => "quantity",
            PlanSpec::FeatureShift { .. } => "feature-shift",
            PlanSpec::Mixed { .. } => "mixed",
        }
    }

    /// The objective node `i` gets under this recipe (`mixed`
    /// alternates hinge/Lasso; everything else is uniform).
    pub fn node_objective(&self, base: Objective, i: usize) -> Objective {
        match self {
            PlanSpec::Mixed { .. } => {
                if i % 2 == 0 {
                    Objective::hinge()
                } else {
                    Objective::lasso()
                }
            }
            _ => base,
        }
    }

    /// Partition an arbitrary base dataset into a plan (synthetic,
    /// notMNIST, or any other [`Dataset`]). Deterministic in
    /// `(self, base, nodes, seed)`. Not meaningful for
    /// [`PlanSpec::Synth`], which generates per-node worlds instead of
    /// splitting a pool — it falls back to an IID split here.
    pub fn build_over(
        &self,
        base: &Dataset,
        objective: Objective,
        nodes: usize,
        seed: u64,
    ) -> WorkloadPlan {
        let mut rng = Xoshiro256pp::seeded(seed ^ 0x5EC7_10);
        let parts = match *self {
            PlanSpec::Synth | PlanSpec::FeatureShift { .. } => {
                partition_iid(base.len(), nodes, &mut rng)
            }
            PlanSpec::Dirichlet { alpha } | PlanSpec::Mixed { alpha } => {
                partition_label_skew(base.labels(), base.classes(), nodes, alpha, &mut rng)
            }
            PlanSpec::Quantity { alpha } => {
                partition_quantity_skew(base.len(), nodes, alpha, &mut rng)
            }
        };
        let assignments = parts
            .into_iter()
            .enumerate()
            .map(|(i, idx)| {
                let mut shard = base.subset(&idx);
                if let PlanSpec::FeatureShift { sigma } = *self {
                    shard = feature_shift(&shard, sigma as f32, &mut rng);
                }
                NodeAssignment::new(self.node_objective(objective, i), shard)
            })
            .collect();
        WorkloadPlan::new(assignments)
    }

    /// Build the full synthetic-world plan plus its held-out global
    /// test set. [`PlanSpec::Synth`] reproduces
    /// [`synth_world`](crate::experiments::synth_world) exactly (so
    /// legacy seeded runs keep their shards); the skew recipes pool
    /// `nodes × samples_per_node` draws of the global mixture and
    /// partition that pool.
    pub fn build(
        &self,
        objective: Objective,
        nodes: usize,
        samples_per_node: usize,
        test_n: usize,
        seed: u64,
    ) -> (WorkloadPlan, Dataset) {
        use crate::data::SyntheticGen;
        if let PlanSpec::Synth = self {
            let (shards, test) =
                crate::experiments::synth_world(nodes, samples_per_node, test_n, seed);
            return (WorkloadPlan::homogeneous(objective, shards), test);
        }
        let gen = SyntheticGen::paper_default(nodes, seed);
        let mut rng = Xoshiro256pp::seeded(seed ^ 0xBA5E);
        let base = gen.global_test_set(nodes * samples_per_node, &mut rng);
        let test = gen.global_test_set(test_n, &mut rng);
        (self.build_over(&base, objective, nodes, seed), test)
    }
}

// ---------------------------------------------------------------------------
// Wire codes (PlanAssign frames carry objectives as a (code, λ) pair)
// ---------------------------------------------------------------------------

/// Serialize an objective for a `PlanAssign` frame. λ is 0 for the
/// unregularized family.
pub fn objective_code(o: Objective) -> (u8, f32) {
    match o {
        Objective::LogReg => (0, 0.0),
        Objective::Hinge { lam } => (1, lam),
        Objective::Lasso { lam } => (2, lam),
    }
}

/// Inverse of [`objective_code`]; `None` for codes this build does not
/// speak (total — wire input is untrusted).
pub fn objective_from_code(code: u8, lam: f32) -> Option<Objective> {
    match code {
        0 => Some(Objective::LogReg),
        1 => Some(Objective::Hinge { lam }),
        2 => Some(Objective::Lasso { lam }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base(rows: usize, classes: usize, seed: u64) -> Dataset {
        let mut rng = Xoshiro256pp::seeded(seed);
        let mut d = Dataset::with_capacity(4, classes, rows);
        for _ in 0..rows {
            let x: Vec<f32> = (0..4).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            d.push(&x, rng.index(classes));
        }
        d
    }

    fn assert_exact_cover(parts: &[Vec<usize>], rows: usize) {
        let mut seen = vec![false; rows];
        for part in parts {
            assert!(!part.is_empty(), "empty shard");
            for &i in part {
                assert!(!seen[i], "row {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&v| v), "rows left unassigned");
    }

    #[test]
    fn partitioners_cover_exactly_once() {
        let d = base(97, 5, 3);
        let mut rng = Xoshiro256pp::seeded(7);
        assert_exact_cover(&partition_iid(97, 6, &mut rng), 97);
        assert_exact_cover(
            &partition_label_skew(d.labels(), 5, 6, 0.2, &mut rng),
            97,
        );
        assert_exact_cover(&partition_quantity_skew(97, 6, 0.3, &mut rng), 97);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Xoshiro256pp::seeded(5);
        for &alpha in &[0.01, 0.5, 5.0] {
            let p = dirichlet(&mut rng, alpha, 8);
            assert_eq!(p.len(), 8);
            assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)));
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "alpha {alpha}: sum {s}");
        }
    }

    #[test]
    fn small_alpha_concentrates_mass() {
        let mut rng = Xoshiro256pp::seeded(11);
        let avg_max = |alpha: f64, rng: &mut Xoshiro256pp| -> f64 {
            (0..50)
                .map(|_| {
                    dirichlet(rng, alpha, 10)
                        .into_iter()
                        .fold(0.0f64, f64::max)
                })
                .sum::<f64>()
                / 50.0
        };
        let sharp = avg_max(0.05, &mut rng);
        let flat = avg_max(50.0, &mut rng);
        assert!(sharp > flat + 0.3, "sharp {sharp} vs flat {flat}");
    }

    #[test]
    fn apportion_is_exact() {
        for (total, props) in [
            (10usize, vec![0.5, 0.5]),
            (7, vec![0.9, 0.05, 0.05]),
            (0, vec![1.0]),
            (13, vec![0.33, 0.33, 0.34]),
        ] {
            let counts = apportion(total, &props);
            assert_eq!(counts.iter().sum::<usize>(), total, "{props:?}");
        }
    }

    #[test]
    fn feature_shift_moves_features_keeps_labels() {
        let d = base(20, 3, 9);
        let mut rng = Xoshiro256pp::seeded(2);
        let shifted = feature_shift(&d, 1.0, &mut rng);
        assert_eq!(shifted.len(), d.len());
        assert_eq!(shifted.labels(), d.labels());
        assert_ne!(shifted.features_flat(), d.features_flat());
        // The shift is a constant per feature: differences are constant
        // across rows.
        let delta0: Vec<f32> = (0..4)
            .map(|k| shifted.sample(0).features[k] - d.sample(0).features[k])
            .collect();
        let delta7: Vec<f32> = (0..4)
            .map(|k| shifted.sample(7).features[k] - d.sample(7).features[k])
            .collect();
        for (a, b) in delta0.iter().zip(&delta7) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn plan_shape_and_census() {
        let (plan, test) = PlanSpec::Mixed { alpha: 0.5 }.build(Objective::LogReg, 6, 30, 64, 1);
        assert_eq!(plan.len(), 6);
        assert_eq!(plan.dim(), 50);
        assert_eq!(plan.param_len(), 50); // hinge/lasso are (dim)-shaped
        assert!(plan.is_mixed());
        let fams = plan.families();
        assert_eq!(fams.len(), 2);
        assert_eq!(fams.iter().map(|(_, c)| c).sum::<usize>(), 6);
        assert_eq!(test.len(), 64);
        // Mixed ignores the base objective (logreg cannot join).
        assert!(plan.objectives().iter().all(|o| o.name() != "logreg"));
    }

    #[test]
    fn plans_carry_per_node_strategies() {
        let (plan, _) = PlanSpec::Synth.build(Objective::LogReg, 4, 25, 16, 9);
        assert!((0..4).all(|i| plan.strategy(i) == StrategyKind::Dasgd));
        assert!(!plan.mixed_strategies());
        let plan = plan
            .with_uniform_strategy(StrategyKind::Rfast)
            .with_node_strategy(2, StrategyKind::Dcasgd);
        assert_eq!(plan.strategy(0), StrategyKind::Rfast);
        assert_eq!(plan.strategy(2), StrategyKind::Dcasgd);
        assert!(plan.mixed_strategies());
        // Switching the objective preserves strategies.
        let plan = plan.with_uniform_objective(Objective::hinge());
        assert_eq!(plan.strategy(2), StrategyKind::Dcasgd);
    }

    #[test]
    fn synth_spec_matches_legacy_world() {
        let (plan, _) = PlanSpec::Synth.build(Objective::LogReg, 4, 25, 16, 9);
        let (shards, _) = crate::experiments::synth_world(4, 25, 16, 9);
        for i in 0..4 {
            assert_eq!(plan.shard(i).labels(), shards[i].labels());
            assert_eq!(plan.shard(i).features_flat(), shards[i].features_flat());
        }
        assert!(!plan.is_mixed());
    }

    #[test]
    #[should_panic(expected = "cannot mix logreg")]
    fn logreg_cannot_mix_with_dim_shaped_families() {
        let d = base(10, 4, 1);
        WorkloadPlan::new(vec![
            NodeAssignment::new(Objective::LogReg, d.subset(&[0, 1, 2])),
            NodeAssignment::new(Objective::hinge(), d.subset(&[3, 4, 5])),
        ]);
    }

    #[test]
    fn partial_plans_fill_placeholders() {
        let d = base(12, 4, 2);
        let assigned = vec![
            (1, NodeAssignment::new(Objective::hinge(), d.subset(&[0, 1]))),
            (2, NodeAssignment::new(Objective::lasso(), d.subset(&[2, 3]))),
        ];
        let plan = WorkloadPlan::from_partial(4, 4, 4, assigned, true).unwrap();
        assert_eq!(plan.len(), 4);
        assert!(plan.shard(0).is_empty());
        assert_eq!(plan.shard(1).len(), 2);
        assert_eq!(plan.param_len(), 4);
        assert!(plan.is_mixed());
        // Errors, not panics, on bad input.
        assert!(WorkloadPlan::from_partial(4, 4, 4, vec![], false).is_err());
        let dup = vec![
            (0, NodeAssignment::new(Objective::hinge(), d.subset(&[0]))),
            (0, NodeAssignment::new(Objective::hinge(), d.subset(&[1]))),
        ];
        assert!(WorkloadPlan::from_partial(4, 4, 4, dup, false).is_err());
    }

    #[test]
    fn partial_plan_inherits_the_deployments_mixed_verdict() {
        // A single-node slice of a mixed deployment looks homogeneous
        // locally; the launcher's PlanStart verdict must win so the
        // per-family stepsize policy survives sharding.
        let d = base(8, 4, 7);
        let one = |mixed: bool| {
            WorkloadPlan::from_partial(
                4,
                4,
                4,
                vec![(2, NodeAssignment::new(Objective::lasso(), d.subset(&[0, 1])))],
                mixed,
            )
            .unwrap()
        };
        assert!(one(true).is_mixed());
        assert!(!one(false).is_mixed());
    }

    #[test]
    fn objective_codes_round_trip() {
        for o in [Objective::LogReg, Objective::hinge(), Objective::lasso()] {
            let (code, lam) = objective_code(o);
            assert_eq!(objective_from_code(code, lam), Some(o));
        }
        assert_eq!(objective_from_code(9, 0.0), None);
    }

    #[test]
    fn spec_parse_names() {
        for name in PlanSpec::NAMES {
            assert_eq!(PlanSpec::parse(name, 0.5).unwrap().name(), name);
        }
        assert_eq!(PlanSpec::parse("wire", 0.5), None);
        assert_eq!(
            PlanSpec::parse("dirichlet", 0.1),
            Some(PlanSpec::Dirichlet { alpha: 0.1 })
        );
    }

    #[test]
    fn shift_sigma_is_its_own_knob_with_a_legacy_fallback() {
        // A dedicated σ wins for feature-shift…
        assert_eq!(
            PlanSpec::parse_spec("feature-shift", 0.5, Some(2.0)),
            Some(PlanSpec::FeatureShift { sigma: 2.0 })
        );
        // …the fallback reproduces the pre-flag behavior (α doubles as σ)…
        assert_eq!(
            PlanSpec::parse_spec("feature-shift", 0.5, None),
            Some(PlanSpec::FeatureShift { sigma: 0.5 })
        );
        // …and σ never leaks into the Dirichlet recipes.
        assert_eq!(
            PlanSpec::parse_spec("dirichlet", 0.5, Some(2.0)),
            Some(PlanSpec::Dirichlet { alpha: 0.5 })
        );
    }

    #[test]
    fn gamma_matches_its_moments_across_the_boost_boundary() {
        // Marsaglia–Tsang applies for α ≥ 1; below it the sampler uses
        // the boost G(α) = G(α+1) · U^{1/α}. A wrong boost exponent
        // (U^α, the classic transcription slip) shifts E[G] far beyond
        // the Monte-Carlo error at this sample count, so pinning the
        // mean — and the second moment, which a compensating error in
        // the α+1 draw could fake — audits the whole α < 1 branch.
        let mut rng = Xoshiro256pp::seeded(13);
        let n = 40_000;
        for &alpha in &[0.15f64, 0.5, 0.95, 1.0, 2.5] {
            let draws: Vec<f64> = (0..n).map(|_| gamma(&mut rng, alpha)).collect();
            let mean = draws.iter().sum::<f64>() / n as f64;
            // Var[G(α,1)] = α ⇒ sd of the sample mean is sqrt(α/n);
            // 6σ keeps the false-failure odds negligible.
            let tol = 6.0 * (alpha / n as f64).sqrt();
            assert!(
                (mean - alpha).abs() < tol,
                "alpha {alpha}: mean {mean} vs expected {alpha} (tol {tol})"
            );
            // E[G²] = α(α+1); Var[G²] = E[G⁴] − E[G²]² with
            // E[G⁴] = α(α+1)(α+2)(α+3).
            let m2 = draws.iter().map(|g| g * g).sum::<f64>() / n as f64;
            let want_m2 = alpha * (alpha + 1.0);
            let var_m2 = alpha * (alpha + 1.0) * (alpha + 2.0) * (alpha + 3.0) - want_m2 * want_m2;
            let tol2 = 6.0 * (var_m2 / n as f64).sqrt();
            assert!(
                (m2 - want_m2).abs() < tol2,
                "alpha {alpha}: E[G²] {m2} vs expected {want_m2} (tol {tol2})"
            );
        }
    }
}
