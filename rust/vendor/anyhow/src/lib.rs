//! Offline shim for the `anyhow` crate: the image this repo builds on
//! resolves no external registry crates, so the workspace vendors the
//! small API subset dasgd actually uses — `Error`, `Result`,
//! `anyhow!`/`bail!`, `Error::msg`, and the `Context` extension trait
//! (`context`/`with_context` on `Result` and `Option`).
//!
//! Semantics match the real crate closely enough to swap back: `{:#}`
//! renders the context chain in one line ("outer: inner"), `{:?}` renders
//! it multi-line with a "Caused by" section, and any `std::error::Error`
//! converts via `?`.

use std::fmt;

/// Dynamic error: a message plus an optional chain of causes.
pub struct Error {
    /// Most recent context first (like anyhow's chain).
    chain: Vec<String>,
}

impl Error {
    /// Build from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            chain: vec![message.to_string()],
        }
    }

    /// Push an outer context message onto the chain.
    pub fn wrap<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, colon-separated, one line.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// `?` on any std error. (Error itself intentionally does not implement
// std::error::Error, exactly like the real anyhow, so this blanket impl
// cannot overlap with `From<Error> for Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (subset of anyhow's `Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!(fmt, args...)` — build an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!(fmt, args...)` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).wrap("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_and_context() {
        fn inner() -> Result<()> {
            bail!("bad value {}", 7);
        }
        let e = inner().unwrap_err();
        assert_eq!(e.root_message(), "bad value 7");

        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: gone");

        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("x").is_err());
    }
}
