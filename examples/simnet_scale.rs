//! The 10,000-node quickstart: Alg. 2 on a 3-regular graph over a lossy
//! network (nonzero per-edge latency, 1% message drop), simulated in
//! virtual time by the sharded event-driven driver.
//!
//! ```text
//! cargo run --release --example simnet_scale
//! cargo run --release --example simnet_scale -- --nodes 10000 --drop-prob 0.01
//! ```
//!
//! At this scale snapshots use the incremental aggregates: the
//! consensus column is the L2 residual `sqrt(Σ‖β_i − β̄‖²)` (zero
//! exactly at consensus), not the paper's d^k sum of norms.

use dasgd::cli::Args;
use dasgd::coordinator::Objective;
use dasgd::experiments::{make_regular, synth_world};
use dasgd::metrics::Table;
use dasgd::sim::{simnet_run, SimConfig, SpeedModel};
use dasgd::transport::{LatencyModel, SimNetConfig};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let n = args.get_usize("nodes", 10_000).map_err(anyhow::Error::msg)?;
    let degree = args.get_usize("degree", 3).map_err(anyhow::Error::msg)?;
    let horizon = args.get_f64("horizon", 40.0).map_err(anyhow::Error::msg)?;
    let drop_prob = args.get_f64("drop-prob", 0.01).map_err(anyhow::Error::msg)?;
    let latency_ms = args.get_f64("latency-ms", 5.0).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 0).map_err(anyhow::Error::msg)?;

    println!("== simnet at scale ==");
    println!(
        "{n} nodes, {degree}-regular, horizon {horizon} virtual s, \
         per-edge latency ≤{latency_ms}ms, drop {:.1}%\n",
        drop_prob * 100.0
    );

    // Small shards keep the world generation fast; the interesting cost
    // is the event loop, not the data.
    let (shards, test) = synth_world(n, 20, 512, seed);
    let g = make_regular(n, degree);
    let speeds = SpeedModel::homogeneous(n, 1.0);
    let objective = Objective::LogReg;
    let cfg = SimConfig {
        p_grad: 0.5,
        stepsize: objective.default_stepsize(n),
        objective,
        horizon,
        eval_every: horizon / 8.0,
        net: SimNetConfig {
            latency: LatencyModel {
                min_secs: latency_ms / 2000.0,
                max_secs: latency_ms / 1000.0,
                jitter_secs: 0.0,
            },
            drop_prob,
            partitions: vec![],
            seed,
        },
        seed,
    };
    let wall = std::time::Instant::now();
    let rep = simnet_run(&g, &shards, &test, &speeds, &cfg);
    let wall = wall.elapsed().as_secs_f64();

    // Small runs scan exactly (d^k); above EXACT_SCAN_MAX the column is
    // the incremental L2 residual.
    let consensus_col = if n <= dasgd::sim::EXACT_SCAN_MAX {
        "d^k"
    } else {
        "L2 resid"
    };
    let mut t = Table::new(&["t (virt s)", "k", consensus_col, "test err"]);
    for r in &rep.recorder.records {
        t.row(&[
            format!("{:.1}", r.time_secs),
            format!("{}", r.k),
            format!("{:.3}", r.consensus),
            format!("{:.3}", r.test_err),
        ]);
    }
    t.print();
    println!(
        "\n{} updates ({} grad, {} proj), {} messages, {} dropped legs — \
         {n} nodes simulated in {wall:.2}s wall",
        rep.updates, rep.grad_steps, rep.proj_steps, rep.messages, rep.drops
    );
    // All-zero init means the residual starts at 0, rises as gradient
    // steps disagree, then falls as gossip wins: peak → last is the
    // decreasing-consensus signal.
    let peak = rep
        .recorder
        .records
        .iter()
        .map(|r| r.consensus)
        .fold(0.0f64, f64::max);
    let last = rep.recorder.last().unwrap().consensus;
    println!("consensus residual peak {peak:.3} → final {last:.3} (falling = gossip wins at scale)");
    Ok(())
}
