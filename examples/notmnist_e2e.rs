//! END-TO-END driver (recorded in EXPERIMENTS.md): the full three-layer
//! system on the paper's §V-E workload.
//!
//! * Layer 1/2: Pallas kernels inside JAX, AOT-lowered to HLO text
//!   (`make artifacts`) — multinomial logistic regression, 256 features
//!   (16×16 glyphs), 10 classes.
//! * Runtime: rust PJRT CPU client compiles + executes the artifacts;
//!   python is NOT running during this binary.
//! * Layer 3: the Alg. 2 coordinator — 30 nodes, 4-regular graph,
//!   per-node data distributions — plus the centralized-SGD baseline and
//!   a live threaded asynchronous phase with the PJRT executor service.
//!
//! ```text
//! make artifacts && cargo run --release --example notmnist_e2e [-- --iters 40000]
//! ```

use dasgd::baselines::CentralizedSgd;
use dasgd::cli::Args;
use dasgd::coordinator::{
    AsyncCluster, AsyncConfig, Backend, PjrtArtifacts, StepSize, TrainConfig,
};
use dasgd::data::Dataset;
use dasgd::experiments::{fig6, make_regular, run_alg2};
use dasgd::metrics::Table;
use dasgd::runtime::ExecutorService;
use dasgd::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let n = 30;
    let degree = 4;
    let iters = args.get_u64("iters", 20_000).map_err(anyhow::Error::msg)?;
    let async_secs = args.get_f64("async-secs", 3.0).map_err(anyhow::Error::msg)?;

    println!("== notMNIST-like end-to-end: 3-layer system ==");
    println!("N = {n} nodes, {degree}-regular, D = 256 features, C = 10 classes\n");

    // ---- Phase 1: sequential Alg. 2 on the PJRT backend -----------------
    let (shards, test) = fig6::notmnist_world(n, 400, 512, 2026);
    let samples: usize = shards.iter().map(Dataset::len).sum();
    println!(
        "corpus: {} training samples across {n} node distributions, 512 test\n",
        samples
    );

    let cfg = TrainConfig {
        stepsize: StepSize::Poly {
            a: 3.0 * n as f32,
            tau: 8000.0,
            pow: 0.75,
        },
        ..TrainConfig::paper_default(n)
    }
    .with_seed(2026)
    .with_backend(Backend::Pjrt);

    println!("[phase 1] Alg. 2, {iters} updates through PJRT (Pallas kernels)…");
    let sw = Stopwatch::new();
    let rec = run_alg2(
        &cfg,
        make_regular(n, degree),
        shards.clone(),
        &test,
        iters,
        (iters / 10).max(1),
        "e2e-pjrt",
    )?;
    let pjrt_secs = sw.elapsed_secs();

    let mut t = Table::new(&["k", "d^k", "test loss", "test err"]);
    for r in &rec.records {
        t.row(&[
            format!("{}", r.k),
            format!("{:.3}", r.consensus),
            format!("{:.4}", r.test_loss),
            format!("{:.4}", r.test_err),
        ]);
    }
    t.print();
    println!(
        "{iters} PJRT-executed updates in {:.1}s = {:.0} updates/s\n",
        pjrt_secs,
        iters as f64 / pjrt_secs
    );

    // ---- Phase 2: centralized SGD reference (§V-E comparison) -----------
    println!("[phase 2] centralized SGD on the pooled corpus…");
    let mut pool = Dataset::new(256, 10);
    for s in &shards {
        pool.extend(s);
    }
    let mut central = CentralizedSgd::new(
        256,
        10,
        StepSize::Poly {
            a: 3.0,
            tau: 8000.0,
            pow: 0.75,
        },
        99,
    );
    let crec = central.run(&pool, &test, iters, iters);
    println!(
        "centralized final error: {:.3}  |  Alg. 2 final error: {:.3}\n",
        crec.final_err(),
        rec.final_err()
    );

    // ---- Phase 3: live asynchronous cluster over the executor service ---
    println!(
        "[phase 3] threaded asynchronous cluster ({async_secs}s, PJRT executor service)…"
    );
    let service = ExecutorService::start("artifacts", 2)?;
    let cluster = AsyncCluster::new(make_regular(n, degree), shards)
        .with_executor(service.handle(), PjrtArtifacts::notmnist());
    let acfg = AsyncConfig {
        p_grad: 0.5,
        stepsize: StepSize::Poly {
            a: 3.0 * n as f32,
            tau: 8000.0,
            pow: 0.75,
        },
        rate_hz: 100.0,
        speed_spread: 0.5,
        duration_secs: async_secs,
        eval_every_secs: async_secs / 4.0,
        gossip_hold_secs: 0.0,
        kill_after_secs: None,
        kill_nodes: 0,
        transport: dasgd::transport::TransportKind::SharedMem,
        seed: 7,
    };
    let rep = cluster.run(&acfg, &test)?;
    println!(
        "async phase: {} updates ({:.0}/s) from 30 unsynchronized threads, {} lock conflicts, final err {:.3}",
        rep.updates,
        rep.updates_per_sec,
        rep.conflicts,
        rep.recorder.last().unwrap().test_err
    );

    // ---- Verdict ---------------------------------------------------------
    let gap = (rec.final_err() - crec.final_err()).abs();
    println!("\n== summary ==");
    println!(
        "decentralized-vs-centralized error gap: {gap:.3} (paper §V-E: 'almost the same result')"
    );
    println!(
        "layers: Pallas kernel → JAX model → HLO text → PJRT (rust) → Alg. 2 coordinator ✓"
    );
    Ok(())
}
