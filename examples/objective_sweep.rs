//! Objective sweep: Fig.-2-style consensus curves for all three §II
//! loss families — logreg, hinge-SVM, and Lasso — on the *same* topology
//! through the *same* `Trainer`/`StepBackend` code path.
//!
//! ```text
//! cargo run --release --example objective_sweep [-- --scale 1.0 --seed 7]
//! ```
//!
//! Each run starts from randomized per-node parameters (init_scale = 1),
//! so d^0 is large and the table shows the Eq. (7) projections dragging
//! every objective's network toward consensus while its metric improves.

use dasgd::cli::Args;
use dasgd::coordinator::{Objective, TrainConfig};
use dasgd::experiments::{make_regular, run_alg2, scaled, synth_world};
use dasgd::metrics::{Recorder, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    args.reject_unknown(&["scale", "seed"])
        .and_then(|()| args.require_values(&["scale", "seed"]))
        .map_err(anyhow::Error::msg)?;
    let scale = args.get_f64("scale", 0.5).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 7).map_err(anyhow::Error::msg)?;

    let n = 12;
    let degree = 4;
    let iters = scaled(12_000, scale, 600);
    let eval_every = (iters / 8).max(1);

    println!("== objective sweep: one trainer, three loss families ==");
    println!("{n} nodes, {degree}-regular graph, {iters} Alg. 2 updates each\n");

    let objectives = [Objective::LogReg, Objective::hinge(), Objective::lasso()];
    let mut series: Vec<(Objective, Recorder)> = Vec::new();
    for obj in objectives {
        let (shards, test) = synth_world(n, 200, 512, seed);
        let cfg = TrainConfig::objective_default(obj, n)
            .with_init_scale(1.0)
            .with_seed(seed);
        let rec = run_alg2(
            &cfg,
            make_regular(n, degree),
            shards,
            &test,
            iters,
            eval_every,
            obj.name(),
        )?;
        series.push((obj, rec));
    }

    // Consensus curves side by side (the Fig. 2 reading, per objective).
    let mut header = vec!["k".to_string()];
    header.extend(series.iter().map(|(o, _)| format!("d^k ({o})")));
    let hdr: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut t = Table::new(&hdr);
    for r in 0..series[0].1.records.len() {
        let mut cells = vec![format!("{}", series[0].1.records[r].k)];
        for (_, rec) in &series {
            cells.push(format!("{:.3}", rec.records[r].consensus));
        }
        t.row(&cells);
    }
    t.print();

    println!();
    let mut m = Table::new(&["objective", "metric", "start", "final", "d^0", "d^final"]);
    for (obj, rec) in &series {
        let first = rec.records.first().unwrap();
        let last = rec.last().unwrap();
        m.row(&[
            obj.name().to_string(),
            match obj {
                Objective::Lasso { .. } => "RMSE".to_string(),
                _ => "error rate".to_string(),
            },
            format!("{:.3}", first.test_err),
            format!("{:.3}", last.test_err),
            format!("{:.2}", first.consensus),
            format!("{:.3}", last.consensus),
        ]);
    }
    m.print();

    println!(
        "\nReading: every loss family reaches consensus (d^k ↓) and improves its \
         metric with purely local gradient + neighborhood-projection steps — the \
         coordinator never special-cases the objective."
    );
    Ok(())
}
