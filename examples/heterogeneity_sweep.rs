//! Heterogeneity sweep: the same 24-node asynchronous system under
//! workloads of rising per-node skew — label-skew Dirichlet α from
//! near-IID down to pathological, quantity skew, covariate shift, and
//! a mixed hinge/Lasso cohort.
//!
//! ```bash
//! cargo run --release --example heterogeneity_sweep
//! cargo run --release --example heterogeneity_sweep -- --scale 1.0 --seed 7
//! ```
//!
//! Each row is one `WorkloadPlan` driven through the event-driven
//! SimNet engine at an identical virtual-time budget; only the data
//! assignment (and, in the last row, the per-node objective) changes.

use dasgd::cli::Args;
use dasgd::experiments::heterogeneity;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let scale = args.get_f64("scale", 0.5).map_err(anyhow::Error::msg)?;
    let seed = args.get_u64("seed", 0).map_err(anyhow::Error::msg)?;
    args.reject_unknown(&["scale", "seed"])
        .map_err(anyhow::Error::msg)?;

    println!("== heterogeneous per-node workloads ==");
    println!(
        "24 nodes, 4-regular, identical virtual-time budget per row \
         (scale {scale}, seed {seed});\nsmaller Dirichlet α = stronger label \
         skew. The mixed row alternates hinge and lasso objectives\nper node \
         and reports the node-weighted per-family metric.\n"
    );
    let rows = heterogeneity::run(scale, seed)?;
    heterogeneity::table(&rows).print();
    for note in heterogeneity::check_shape(&rows) {
        println!("  {note}");
    }
    println!(
        "\nSame sweep via the CLI: `dasgd heterogeneity`, or one point with\n\
         `dasgd sim --plan dirichlet --dirichlet-alpha 0.1` — and the \
         multi-process path:\n`dasgd launch --workers 2 --plan mixed \
         --dirichlet-alpha 0.1` (shards ship over TCP)."
    );
    Ok(())
}
