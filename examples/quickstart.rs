//! Quickstart: run Alg. 2 on a small networked system and watch global
//! consensus + prediction error improve with purely local operations.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! Uses the rust-native backend so it runs even before `make artifacts`;
//! pass `--backend pjrt` (after `make artifacts`) to execute the
//! AOT-compiled Pallas kernels instead, and `--objective hinge` or
//! `--objective lasso` to optimize a different §II loss family through
//! the same trainer.

use dasgd::cli::Args;
use dasgd::coordinator::{Backend, Objective, TrainConfig};
use dasgd::experiments::{make_regular, run_alg2, synth_world};
use dasgd::metrics::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    args.reject_unknown(&["backend", "objective", "iters"])
        .and_then(|()| args.require_values(&["backend", "objective", "iters"]))
        .map_err(anyhow::Error::msg)?;
    let backend = match args.get_str("backend", "native") {
        "pjrt" => Backend::Pjrt,
        "native" => Backend::Native,
        other => anyhow::bail!("unknown backend {other:?} (choose: native, pjrt)"),
    };
    let objective = Objective::parse(args.get_str("objective", "logreg"))
        .ok_or_else(|| anyhow::anyhow!("unknown objective (try: logreg, hinge, lasso)"))?;
    let n = 12;
    let degree = 4;
    let iters = args.get_u64("iters", 6000).map_err(anyhow::Error::msg)?;

    println!("== dasgd quickstart ==");
    println!(
        "{n} nodes, {degree}-regular graph, {iters} Alg. 2 updates, \
         {objective} objective, {backend:?} backend\n"
    );

    // 1. A networked world: per-node data distributions + a global test set.
    let (shards, test) = synth_world(n, 300, 512, 42);

    // 2. The paper's Alg. 2 with default settings (p_grad = 0.5,
    //    diminishing steps tuned per objective).
    let cfg = TrainConfig::objective_default(objective, n)
        .with_seed(42)
        .with_backend(backend);

    // 3. Run and report.
    let rec = run_alg2(
        &cfg,
        make_regular(n, degree),
        shards,
        &test,
        iters,
        iters / 8,
        "quickstart",
    )?;

    let mut t = Table::new(&["k", "consensus d^k", "test loss", "test err"]);
    for r in &rec.records {
        t.row(&[
            format!("{}", r.k),
            format!("{:.4}", r.consensus),
            format!("{:.4}", r.test_loss),
            format!("{:.4}", r.test_err),
        ]);
    }
    t.print();

    let first = rec.records.first().unwrap();
    let last = rec.last().unwrap();
    match objective {
        Objective::Lasso { .. } => println!(
            "\nprediction RMSE {:.3} → {:.3}",
            first.test_err, last.test_err
        ),
        Objective::Hinge { .. } => println!(
            "\nbinary error {:.3} → {:.3} (random guess would be 0.500)",
            first.test_err, last.test_err
        ),
        Objective::LogReg => println!(
            "\nprediction error {:.3} → {:.3} (random guess would be {:.3})",
            first.test_err,
            last.test_err,
            1.0 - 1.0 / test.classes() as f64
        ),
    }
    println!(
        "all with LOCAL operations only: {} gradient steps, {} neighborhood averages, {} messages",
        last.grad_steps, last.proj_steps, last.messages
    );
    Ok(())
}
