//! Topology sweep: how network structure drives convergence.
//!
//! Computes the Lemma-1 spectral quantities (σ₂ of the averaging matrix,
//! the η lower bound, the Theorem-2 contraction constant) for a family
//! of topologies and cross-checks them against measured consensus speed
//! from projection-only Alg. 2 runs.
//!
//! ```text
//! cargo run --release --example topology_sweep [--scale 1.0]
//! ```

use dasgd::cli::Args;
use dasgd::coordinator::{NativeBackend, TrainConfig, Trainer};
use dasgd::experiments::{make_regular, synth_world};
use dasgd::graph::{complete, ring, spectral, two_clusters, Graph};
use dasgd::metrics::Table;

fn consensus_halvings(graph: Graph, iters: u64, seed: u64) -> f64 {
    let n = graph.len();
    let (shards, test) = synth_world(n, 10, 64, seed);
    let cfg = TrainConfig::paper_default(n)
        .with_p_grad(0.0) // pure consensus dynamics
        .with_init_scale(1.0)
        .with_seed(seed);
    let mut t = Trainer::new(cfg, graph, shards, NativeBackend::new(50, 10));
    let d0 = t.consensus_distance();
    t.run(iters, iters, &test, "sweep").unwrap();
    let d1 = t.consensus_distance();
    (d0 / d1.max(1e-300)).log2()
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let scale = args.get_f64("scale", 1.0).map_err(anyhow::Error::msg)?;
    let n = 30;
    let iters = ((600.0 * scale) as u64).max(150);

    println!("== topology sweep: spectral bounds vs measured consensus ==");
    println!("N = {n}, {iters} projection steps per topology\n");

    let topologies: Vec<(&str, Graph)> = vec![
        ("ring (k=2)", ring(n)),
        ("4-regular", make_regular(n, 4)),
        ("10-regular", make_regular(n, 10)),
        ("15-regular", make_regular(n, 15)),
        ("two clusters", two_clusters(n / 2)),
        ("complete", complete(n)),
    ];

    let mut t = Table::new(&[
        "topology",
        "edges",
        "diam",
        "sigma2(A)",
        "eta bound",
        "measured d^k halvings",
    ]);
    for (name, g) in topologies {
        let s2 = spectral::sigma2(&g, 300);
        // Lemma 1 is stated for regular graphs; report "-" otherwise.
        let eta = if g.is_regular().is_some() {
            format!("{:.5}", spectral::lemma1_eta_lower_bound(&g))
        } else {
            "-".to_string()
        };
        let halvings = consensus_halvings(g.clone(), iters, 7);
        t.row(&[
            name.to_string(),
            format!("{}", g.edge_count()),
            format!("{}", g.diameter().unwrap_or(0)),
            format!("{:.4}", s2),
            eta,
            format!("{:.1}", halvings),
        ]);
    }
    t.print();
    println!(
        "\nReading: smaller sigma2 / larger eta bound ⇒ more d^k halvings in the \
         same budget — Lemma 1's ordering, measured."
    );
    Ok(())
}
