//! Live asynchronous cluster: one OS thread per node, no barriers, no
//! coordinator — the deployment §IV describes, including heterogeneous
//! node speeds and the neighbor lock-up protocol.
//!
//! ```text
//! cargo run --release --example async_cluster -- --secs 4 --spread 1.0
//! ```

use dasgd::cli::Args;
use dasgd::coordinator::{AsyncCluster, AsyncConfig, StepSize};
use dasgd::experiments::{make_regular, synth_world};
use dasgd::metrics::Table;
use dasgd::transport::TransportKind;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let n = args.get_usize("nodes", 16).map_err(anyhow::Error::msg)?;
    let degree = args.get_usize("degree", 4).map_err(anyhow::Error::msg)?;
    let secs = args.get_f64("secs", 3.0).map_err(anyhow::Error::msg)?;
    let spread = args.get_f64("spread", 0.8).map_err(anyhow::Error::msg)?;
    let transport = TransportKind::parse(args.get_str("transport", "shared")).ok_or_else(|| {
        anyhow::anyhow!("--transport wants shared|channel (socket runs via `dasgd launch`)")
    })?;

    println!("== asynchronous cluster ==");
    println!(
        "{n} node threads, {degree}-regular, {secs}s, speed spread {spread} \
         (≈{:.0}x rate disparity), transport {}\n",
        (2.0 * spread).exp(),
        transport.name()
    );

    let (shards, test) = synth_world(n, 300, 512, 11);
    let cluster = AsyncCluster::new(make_regular(n, degree), shards);
    let cfg = AsyncConfig {
        p_grad: 0.5,
        stepsize: StepSize::paper_default(n),
        rate_hz: 400.0,
        speed_spread: spread,
        duration_secs: secs,
        eval_every_secs: secs / 8.0,
        gossip_hold_secs: 0.0,
        kill_after_secs: None,
        kill_nodes: 0,
        transport,
        seed: 11,
    };
    let rep = cluster.run(&cfg, &test)?;

    let mut t = Table::new(&["t (s)", "updates", "d^k", "test err", "lock conflicts"]);
    for r in &rep.recorder.records {
        t.row(&[
            format!("{:.2}", r.time_secs),
            format!("{}", r.k),
            format!("{:.3}", r.consensus),
            format!("{:.3}", r.test_err),
            format!("{}", r.conflicts),
        ]);
    }
    t.print();

    println!(
        "\n{} updates in {secs}s = {:.0} updates/s across {n} unsynchronized threads",
        rep.updates, rep.updates_per_sec
    );
    println!(
        "{} gradient steps, {} projections, {} messages, {} lock-up backoffs",
        rep.grad_steps, rep.proj_steps, rep.messages, rep.conflicts
    );
    println!(
        "final error {:.3} — stragglers slowed only themselves, never the cluster",
        rep.recorder.last().unwrap().test_err
    );
    Ok(())
}
