import os
import sys

# Make `compile.*` importable when pytest runs from the repo root
# (the canonical invocation is `pytest python/tests/`).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
