//! Bench: regenerate paper Fig. 2 (distance to global consensus, 30
//! nodes, 4-regular vs 15-regular) and time the consensus machinery.
//!
//! `DASGD_BENCH_SCALE` (default 0.25) scales the iteration budget;
//! 1.0 = the paper's 20k updates.

use dasgd::bench::Harness;
use dasgd::coordinator::consensus;
use dasgd::experiments::fig2;
use dasgd::util::rng::Xoshiro256pp;

fn scale() -> f64 {
    std::env::var("DASGD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.25)
}

fn main() {
    let s = scale();
    println!("# Fig. 2 — distance to global consensus (scale {s})");
    let r = fig2::run(s, 0).expect("fig2");
    r.table().print();
    for note in fig2::check_shape(&r) {
        println!("  {note}");
    }
    for (name, rec) in &r.series {
        println!(
            "  {name}: k to d<10 = {:?} (paper: ~10k at scale 1.0)",
            rec.k_to_consensus_below(10.0)
        );
    }

    // Microbenchmarks of the metric hot path used during the sweep.
    let mut h = Harness::new("fig2 machinery");
    let mut rng = Xoshiro256pp::seeded(1);
    let params: Vec<Vec<f32>> = (0..30)
        .map(|_| (0..500).map(|_| rng.gauss_f32(0.0, 1.0)).collect())
        .collect();
    h.case("consensus_distance(30x500)", || {
        std::hint::black_box(consensus::consensus_distance(&params));
    });
    let g = dasgd::experiments::make_regular(30, 4);
    h.case("feasibility_DF(30x500, 4-regular)", || {
        std::hint::black_box(consensus::feasibility(&params, &g));
    });
}
