//! Bench: regenerate paper Fig. 6 (notMNIST-like prediction error,
//! 4- vs 15-regular, + the centralized-SGD reference).
//! `DASGD_BENCH_SCALE` (default 0.1) scales the 40k-iteration budget.

use dasgd::experiments::fig6;

fn main() {
    let s = std::env::var("DASGD_BENCH_SCALE")
        .ok()
        .and_then(|x| x.parse().ok())
        .unwrap_or(0.1);
    println!("# Fig. 6 — notMNIST-like prediction error (scale {s})");
    let r = fig6::run(s, 0).expect("fig6");
    r.table().print();
    for note in fig6::check_shape(&r) {
        println!("  {note}");
    }
    println!("  paper reading at scale 1.0: error → <0.1, ≈ centralized SGD");
}
