//! Bench: regenerate paper Fig. 4 (final prediction error vs network
//! size N ∈ {10..30}, degree 4 vs 10, 500 samples/node).
//! `DASGD_BENCH_SCALE` (default 0.15) scales the per-point budget.

use dasgd::experiments::fig4;

fn main() {
    let s = std::env::var("DASGD_BENCH_SCALE")
        .ok()
        .and_then(|x| x.parse().ok())
        .unwrap_or(0.15);
    println!("# Fig. 4 — final error vs network size (scale {s})");
    let r = fig4::run(s, 0).expect("fig4");
    r.table().print();
    for note in fig4::check_shape(&r) {
        println!("  {note}");
    }
}
