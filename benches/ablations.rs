//! Bench: the §IV ablations — communication overhead (p_grad sweep),
//! update conflicts (lock-up vs ignore), topology families, and the
//! straggler comparison (async Alg. 2 vs sync DSGD vs server-worker in
//! virtual time).

use dasgd::experiments::{ablations, losses, straggler};

fn main() {
    let s = std::env::var("DASGD_BENCH_SCALE")
        .ok()
        .and_then(|x| x.parse().ok())
        .unwrap_or(0.2);

    println!("# §IV-B — communication overhead vs p_grad (scale {s})");
    let rows = ablations::comm_overhead(s, 0).expect("comm");
    ablations::comm_table(&rows).print();

    println!("\n# §IV-C — update conflicts under distributed selection");
    let rows = ablations::conflicts(s, 0).expect("conflicts");
    ablations::conflict_table(&rows).print();

    println!("\n# topology families");
    let rows = ablations::topologies(s, 0).expect("topologies");
    ablations::topology_table(&rows).print();

    println!("\n# §II loss families — decentralized SVM + Lasso");
    let rows = losses::run(s, 0).expect("losses");
    losses::table(&rows).print();

    println!("\n# stragglers — virtual-time comparison");
    let rows = straggler::run(s, 0).expect("straggler");
    straggler::table(&rows).print();
    for note in straggler::check_shape(&rows) {
        println!("  {note}");
    }
}
