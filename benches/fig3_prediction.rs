//! Bench: regenerate paper Fig. 3 (prediction error vs iterations,
//! 30 nodes, 2-regular vs 10-regular). `DASGD_BENCH_SCALE` (default
//! 0.25) scales the budget; 1.0 = the paper's 40k iterations.

use dasgd::experiments::fig3;

fn main() {
    let s = std::env::var("DASGD_BENCH_SCALE")
        .ok()
        .and_then(|x| x.parse().ok())
        .unwrap_or(0.25);
    println!("# Fig. 3 — prediction error (scale {s})");
    let r = fig3::run(s, 0).expect("fig3");
    r.table().print();
    for note in fig3::check_shape(&r) {
        println!("  {note}");
    }
    println!(
        "  paper reading at scale 1.0: error < 0.4 after 40k iters; random guess 0.9"
    );
}
