//! Bench: the Lemma 1 table — spectral η lower bound vs measured DF
//! contraction across a degree sweep on N = 30, plus power-iteration
//! timing.

use dasgd::bench::Harness;
use dasgd::experiments::lemma1;
use dasgd::graph::spectral;

fn main() {
    let s = std::env::var("DASGD_BENCH_SCALE")
        .ok()
        .and_then(|x| x.parse().ok())
        .unwrap_or(0.5);
    println!("# Lemma 1 — eta bound vs measured contraction (scale {s})");
    let r = lemma1::run(s, 0).expect("lemma1");
    r.table().print();
    for note in lemma1::check_shape(&r) {
        println!("  {note}");
    }

    let mut h = Harness::new("spectral machinery");
    let g = dasgd::experiments::make_regular(30, 4);
    h.case("sigma2 power-iteration (N=30, 200 iters)", || {
        std::hint::black_box(spectral::sigma2(&g, 200));
    });
    h.case("averaging_matrix (N=30)", || {
        std::hint::black_box(spectral::averaging_matrix(&g));
    });
}
