//! Bench: runtime hot-path microbenchmarks — the latency of every PJRT
//! artifact call vs its rust-native equivalent, plus coordinator
//! machinery (selection, RNG, gossip stacking). This is the §Perf
//! measurement harness for L3.
//!
//! Requires `make artifacts`; PJRT cases are skipped (with a note) if
//! the artifact set is missing.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use dasgd::bench::Harness;
use dasgd::coordinator::{CentralSelector, GeometricSelector};
use dasgd::model::LogReg;
use dasgd::net::wire::{self, WireMsg};
use dasgd::net::{ShardMap, SocketConfig, SocketNet};
use dasgd::node_logic::neighborhood_average;
use dasgd::runtime::Engine;
use dasgd::transport::{
    ChannelNet, ProjectionOutcome, SharedMem, SimNet, SimNetConfig, Transport,
};
use dasgd::util::rng::Xoshiro256pp;

/// One projection round (collect + average + broadcast) over the closed
/// neighborhood {4, 5, 6} of the middle node of a ring-10, on `t`.
fn projection_round(t: &dyn Transport) -> ProjectionOutcome {
    t.try_project(5, &[4, 5, 6], Duration::ZERO, &mut |rows, _aux| {
        (neighborhood_average(rows), Vec::new())
    })
}

/// Transport micro-bench: the same ring-10 projection round on every
/// substrate; appends results to the harness and returns (name, mean s)
/// rows for BENCH_transport.json.
fn bench_transports(h: &mut Harness, param_len: usize) -> Vec<(String, f64)> {
    let mut rows = Vec::new();

    let shared = SharedMem::new(10, param_len);
    let r = h.case("projection round ring-10 SharedMem", || {
        assert!(matches!(
            projection_round(&shared),
            ProjectionOutcome::Applied { .. }
        ));
    });
    rows.push(("shared_mem".to_string(), r.mean_secs));

    // Channel needs the two peers' mailboxes pumped from other threads.
    let channel = Arc::new(ChannelNet::with_default_timeout(10, param_len));
    let stop = Arc::new(AtomicBool::new(false));
    let pumps: Vec<_> = [4usize, 6]
        .iter()
        .map(|&j| {
            let net = Arc::clone(&channel);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    net.poll(j);
                    std::hint::spin_loop();
                }
            })
        })
        .collect();
    let r = h.case("projection round ring-10 Channel", || {
        assert!(matches!(
            projection_round(channel.as_ref()),
            ProjectionOutcome::Applied { .. }
        ));
    });
    rows.push(("channel".to_string(), r.mean_secs));
    stop.store(true, Ordering::Relaxed);
    for p in pumps {
        let _ = p.join();
    }

    let simnet = SimNet::new(10, param_len, SimNetConfig::ideal(0.005));
    let r = h.case("projection round ring-10 SimNet", || {
        assert!(matches!(
            projection_round(&simnet),
            ProjectionOutcome::Applied { .. }
        ));
        let _ = simnet.take_last_comm();
    });
    rows.push(("simnet".to_string(), r.mean_secs));

    // SocketNet: the same round where one leg (node 4) crosses a real
    // loopback TCP connection between two shard processes-worth of
    // state (ranks 0 and 1 in this process).
    let map = ShardMap::new(10, 2);
    let a = SocketNet::bind(0, map, param_len, "127.0.0.1:0", SocketConfig::default())
        .expect("bind rank 0");
    let b = SocketNet::bind(1, map, param_len, "127.0.0.1:0", SocketConfig::default())
        .expect("bind rank 1");
    let peers = vec![a.local_addr().to_string(), b.local_addr().to_string()];
    a.connect_peers(&peers);
    b.connect_peers(&peers);
    assert!(a.wait_connected(Duration::from_secs(5)));
    assert!(b.wait_connected(Duration::from_secs(5)));
    let stop = Arc::new(AtomicBool::new(false));
    let pumps: Vec<_> = [(a.clone(), 4usize), (b.clone(), 6usize)]
        .into_iter()
        .map(|(net, j)| {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                while !stop.load(Ordering::Relaxed) {
                    net.poll(j);
                    std::hint::spin_loop();
                }
            })
        })
        .collect();
    let r = h.case("projection round ring-10 SocketNet loopback", || {
        assert!(matches!(
            projection_round(&b),
            ProjectionOutcome::Applied { .. }
        ));
    });
    rows.push(("socket_loopback".to_string(), r.mean_secs));
    stop.store(true, Ordering::Relaxed);
    for p in pumps {
        let _ = p.join();
    }
    a.shutdown();
    b.shutdown();
    rows
}

/// Wire-codec micro-bench: encode/decode of a projection reply carrying
/// a `param_len`-dim vector (the deployment's dominant frame), plus the
/// chunk envelope on a shard-sized `PlanAssign` (the `launch` shipping
/// path for quantity-skewed worlds).
fn bench_wire(h: &mut Harness, param_len: usize) -> Vec<(String, f64)> {
    let msg = WireMsg::ApplyAverage {
        from: 5,
        to: 4,
        token: 99,
        w: (0..param_len).map(|i| i as f32 * 0.25).collect(),
        aux: Vec::new(),
    };
    let mut rows = Vec::new();
    let r = h.case("wire encode (ApplyAverage, 500 dims)", || {
        std::hint::black_box(wire::encode(&msg).unwrap());
    });
    rows.push(("wire_encode".to_string(), r.mean_secs));
    let frame = wire::encode(&msg).unwrap();
    let r = h.case("wire decode (ApplyAverage, 500 dims)", || {
        std::hint::black_box(wire::decode(&frame).unwrap().unwrap());
    });
    rows.push(("wire_decode".to_string(), r.mean_secs));

    // Chunked logical messages: a ~20 MiB PlanAssign (100k rows × 50
    // features) split into the ChunkBegin/Data/End envelope and
    // reassembled — the whole-shard cost a launch pays per node.
    let rows_n = 100_000usize;
    let big = WireMsg::PlanAssign {
        node: 0,
        obj_code: 0,
        lam: 0.0,
        dim: 50,
        classes: 10,
        labels: (0..rows_n as u32).map(|i| i % 10).collect(),
        features: (0..rows_n * 50).map(|i| i as f32 * 0.125).collect(),
        strategy: 0,
    };
    let r = h.case("wire chunk encode (20 MiB PlanAssign)", || {
        std::hint::black_box(wire::encode_message(&big).unwrap());
    });
    rows.push(("wire_chunk_encode".to_string(), r.mean_secs));
    let stream = wire::encode_message(&big).unwrap().concat();
    let r = h.case("wire chunk reassemble (20 MiB PlanAssign)", || {
        let mut asm = wire::ChunkAssembler::new();
        let mut cursor = std::io::Cursor::new(&stream);
        std::hint::black_box(wire::read_message(&mut cursor, &mut asm).unwrap());
    });
    rows.push(("wire_chunk_reassemble".to_string(), r.mean_secs));
    rows
}

/// Streaming data-plane micro-bench: the per-shard cost of the block
/// pipeline (carve → validate → fold → staging push → drain), and the
/// latency from the first block landing in a fresh [`BlockBuffer`] to a
/// node-side receiver holding trainable rows — the "first step" lead
/// the streaming plane buys over ship-whole-shard.
fn bench_stream(h: &mut Harness) -> Vec<(String, f64)> {
    use dasgd::data::stream::{BlockBuffer, RowBlock, StreamProgress, DEFAULT_BLOCK_ROWS};
    use dasgd::data::Dataset;

    let (dim, classes, rows_n) = (50usize, 10usize, 20_000usize);
    let mut shard = Dataset::with_capacity(dim, classes, rows_n);
    let mut rng = Xoshiro256pp::seeded(17);
    let mut row = vec![0.0f32; dim];
    for i in 0..rows_n {
        for v in row.iter_mut() {
            *v = rng.gauss_f32(0.0, 1.0);
        }
        shard.push(&row, i % classes);
    }
    let shard_bytes = (rows_n * (dim + 1) * 4) as f64;
    let blocks = RowBlock::carve(0, &shard, DEFAULT_BLOCK_ROWS);

    let mut out = Vec::new();
    let r = h.case("shard stream (20k rows: carve+fold+stage+drain)", || {
        let carved = RowBlock::carve(0, &shard, DEFAULT_BLOCK_ROWS);
        let buffer = BlockBuffer::new(1, u64::MAX);
        let receiver = buffer.receiver(0);
        let mut progress = StreamProgress::default();
        let mut rebuilt = Dataset::with_capacity(dim, classes, rows_n);
        for b in carved {
            b.validate(dim, classes).unwrap();
            progress.fold(&b).unwrap();
            buffer.push(b).unwrap();
            receiver.drain_into(&mut rebuilt);
        }
        assert_eq!(rebuilt.len(), rows_n);
        std::hint::black_box(progress.checksum());
    });
    println!(
        "  shard_stream_throughput ≈ {:.0} MiB/s",
        shard_bytes / r.mean_secs / (1024.0 * 1024.0)
    );
    out.push(("shard_stream_throughput".to_string(), r.mean_secs));

    let first = blocks[0].clone();
    let r = h.case("stream first-step latency (one block: stage+drain)", || {
        let buffer = BlockBuffer::new(1, u64::MAX);
        let receiver = buffer.receiver(0);
        let mut staged = Dataset::with_capacity(dim, classes, DEFAULT_BLOCK_ROWS);
        buffer.push(first.clone()).unwrap();
        receiver.drain_into(&mut staged);
        assert!(staged.len() > 0);
        std::hint::black_box(staged.len());
    });
    out.push(("stream_first_step_latency".to_string(), r.mean_secs));
    out
}

/// Scheduler saturation: how many nodes one process can drive. Runs a
/// 512-node ring on [`SharedMem`] for a fixed wall window twice — the
/// thread-per-node baseline, then the work-stealing executor pool —
/// and reports *seconds per applied update* for each (lower is better,
/// like every other row). The printed ratio is the scheduler's
/// nodes-per-worker win: the pool runs due tasks back-to-back on a few
/// cores instead of context-switching 512 parked threads.
fn bench_saturation() -> Vec<(String, f64)> {
    use dasgd::coordinator::{spawn_shard, AsyncConfig, EngineKind, Objective};
    use dasgd::data::{Dataset, SyntheticGen};
    use dasgd::workload::WorkloadPlan;

    const NODES: usize = 512;
    const WINDOW_SECS: f64 = 1.5;
    let gen = SyntheticGen::new(NODES, 10, 4, 2.0, 0.5, 0.3, 11);
    let mut rng = Xoshiro256pp::seeded(11);
    let shards: Vec<Dataset> = (0..NODES)
        .map(|i| gen.node_dataset(i, 20, &mut rng))
        .collect();
    let plan = WorkloadPlan::homogeneous(Objective::LogReg, shards);
    let graph = dasgd::experiments::make_regular(NODES, 4);
    let mut run_engine = |engine: EngineKind| -> f64 {
        let cfg = AsyncConfig {
            rate_hz: 1000.0,
            engine,
            ..AsyncConfig::quick(NODES)
        };
        let transport: Arc<dyn Transport> = Arc::new(SharedMem::new(NODES, plan.param_len()));
        let run = spawn_shard(&graph, &plan, &cfg, transport, 0..NODES, None);
        std::thread::sleep(Duration::from_secs_f64(WINDOW_SECS));
        let counts = run.stop_and_join();
        (counts.updates() as f64 / WINDOW_SECS).max(1e-9)
    };
    let tpn = run_engine(EngineKind::ThreadPerNode);
    let pool = run_engine(EngineKind::Executors(0));
    println!(
        "  nodes_per_worker_saturation (512 nodes, 1 process): pool {pool:.0} vs \
         thread-per-node {tpn:.0} updates/s — ×{:.1}",
        pool / tpn
    );
    vec![
        ("nodes_per_worker_saturation".to_string(), 1.0 / pool),
        ("nodes_per_worker_tpn_baseline".to_string(), 1.0 / tpn),
    ]
}

/// Observability overhead: the cost of one fully-instrumented record
/// (counter + histogram observe + disabled trace probe — what every
/// fire adds), and the disabled trace probe alone (what non-firing hot
/// paths pay). Both must stay in the nanoseconds for the ≤5% budget the
/// CI gate enforces on `socket_loopback`.
fn bench_obs(h: &mut Harness) -> Vec<(String, f64)> {
    use dasgd::obs::{self, Counter, Hist};
    let mut rows = Vec::new();
    let mut v = 0u64;
    let r = h.case("metrics hot path (counter + histogram + trace off)", || {
        v = v.wrapping_add(17);
        obs::add(Counter::Steals, 1);
        obs::observe(Hist::StalenessTicks, v & 0xFFFF);
        obs::trace("bench", "noop", 0, v);
    });
    rows.push(("metrics_hot_path".to_string(), r.mean_secs));
    let r = h.case("trace probe, tracing disabled", || {
        obs::trace("bench", "noop", 0, std::hint::black_box(7));
    });
    rows.push(("trace_disabled_overhead".to_string(), r.mean_secs));
    rows
}

/// Membership repair latency at deployment scale: one worker-sized
/// block of nodes (250 of 1000) vacated and re-admitted on a 4-regular
/// graph — the monitor-side cost of one churn event (eviction + join,
/// `rust/src/membership/`). 1000 active nodes is far past the exact-σ₂
/// scorer's cutoff, so this measures the BFS expansion-proxy path the
/// large runs actually take. The row is seconds per full cycle.
fn bench_membership(h: &mut Harness) -> Vec<(String, f64)> {
    use dasgd::membership::Membership;

    const NODES: usize = 1000;
    const DEGREE: usize = 4;
    let mut m = Membership::new(dasgd::experiments::make_regular(NODES, DEGREE), DEGREE);
    // The block a 4-worker launch would vacate when rank 1 dies.
    let block: Vec<usize> = (250..500).collect();
    let r = h.case("membership repair (1k nodes, vacate + re-admit 250)", || {
        std::hint::black_box(m.deactivate(&block).len());
        std::hint::black_box(m.activate(&block).len());
    });
    assert!(m.is_active_connected());
    vec![("membership_repair".to_string(), r.mean_secs)]
}

/// Strategy dispatch overhead: one Eq. (6) gradient event routed the
/// way every engine now runs it — an action draw plus `local_step`
/// through the `Box<dyn Strategy>` vtable, aux blob threaded — against
/// the same event calling `NodeLogic::native_grad_step` directly (the
/// pre-zoo welded path). Both sides consume identical RNG streams on
/// identical shards, so the difference is exactly the dispatch tax the
/// algorithm-zoo factoring adds per fire. The CI gate holds
/// `strategy_dispatch_overhead` to a 5% budget against the committed
/// baseline, the same tight leash as the socket hot path.
fn bench_strategy(h: &mut Harness) -> Vec<(String, f64)> {
    use dasgd::coordinator::Objective;
    use dasgd::data::{Dataset, SyntheticGen};
    use dasgd::node_logic::{NodeLogic, Strategy, StrategyKind};

    let gen = SyntheticGen::new(2, 10, 4, 2.0, 0.5, 0.3, 23);
    let mut rng = Xoshiro256pp::seeded(23);
    let shard: Dataset = gen.node_dataset(0, 40, &mut rng);
    let mk_logic = || {
        NodeLogic::new(
            0,
            Objective::LogReg,
            0.5,
            shard.clone(),
            2,
            Xoshiro256pp::seeded(23).split(0),
        )
    };

    let mut rows = Vec::new();
    let lr = 0.01f32;

    let mut logic = mk_logic();
    let mut strat = StrategyKind::Dasgd.build(lr);
    let mut w = vec![0.0f32; logic.param_len()];
    let mut aux = Vec::new();
    let r = h.case("grad event via Box<dyn Strategy> (dasgd, 50x10)", || {
        let _ = strat.draw_action(&mut logic);
        std::hint::black_box(strat.local_step(&mut logic, &mut w, &mut aux, lr, 0));
    });
    rows.push(("strategy_dispatch_overhead".to_string(), r.mean_secs));
    let trait_mean = r.mean_secs;

    let mut logic = mk_logic();
    let mut w = vec![0.0f32; logic.param_len()];
    let r = h.case("grad event direct (native_grad_step, 50x10)", || {
        let _ = logic.draw_action();
        std::hint::black_box(logic.native_grad_step(&mut w, lr));
    });
    rows.push(("strategy_direct_baseline".to_string(), r.mean_secs));
    println!(
        "  strategy dispatch tax: trait {trait_mean:.3e}s vs direct {:.3e}s — ×{:.3} \
         (hot-path budget 1.05x)",
        r.mean_secs,
        trait_mean / r.mean_secs
    );
    rows
}

fn write_transport_baseline(rows: &[(String, f64)], param_len: usize) {
    let mut body = String::from("{\n  \"bench\": \"transport_projection_round\",\n");
    body.push_str(
        "  \"topology\": \"ring-10, closed neighborhood of 3; wire_encode/decode are \
         codec-only on a 500-dim ApplyAverage frame; wire_chunk_* are the chunk \
         envelope on a 20 MiB PlanAssign; shard_stream_throughput is the block \
         pipeline (carve+fold+stage+drain) over a 20k-row shard and \
         stream_first_step_latency is one staged block reaching a node; \
         metrics_hot_path is one instrumented record (counter + histogram + \
         disabled trace probe) and trace_disabled_overhead the probe alone; \
         membership_repair is one 1000-node churn cycle (vacate + re-admit a \
         250-node worker block, topology repaired both ways); \
         strategy_dispatch_overhead is one gradient event through the \
         Box<dyn Strategy> layer on the baseline strategy and \
         strategy_direct_baseline the same event calling native_grad_step \
         directly (the dispatch tax, budgeted at 5%); \
         nodes_per_worker_saturation is seconds per applied update with 512 \
         nodes on the executor pool in one process (nodes_per_worker_tpn_baseline \
         is the same window on thread-per-node)\",\n",
    );
    body.push_str(&format!("  \"param_len\": {param_len},\n  \"mean_secs\": {{\n"));
    for (i, (name, mean)) in rows.iter().enumerate() {
        let comma = if i + 1 < rows.len() { "," } else { "" };
        body.push_str(&format!("    \"{name}\": {mean:e}{comma}\n"));
    }
    body.push_str("  }\n}\n");
    match std::fs::write("BENCH_transport.json", &body) {
        Ok(()) => println!("\nwrote BENCH_transport.json"),
        Err(e) => println!("\n(could not write BENCH_transport.json: {e})"),
    }
}

fn main() {
    let mut rng = Xoshiro256pp::seeded(3);

    // ---- native math ------------------------------------------------------
    let mut h = Harness::new("native math (L3 fallback path)");
    let (d, c) = (50usize, 10usize);
    let w: Vec<f32> = (0..d * c).map(|_| rng.gauss_f32(0.0, 0.2)).collect();
    let x: Vec<f32> = (0..d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let mut model = LogReg::from_weights(d, c, w.clone());
    h.case("logreg grad step (50x10, b=1) native", || {
        std::hint::black_box(model.sgd_step(&[&x], &[3], 0.1, 1.0));
    });
    let rows: Vec<Vec<f32>> = (0..5)
        .map(|_| (0..d * c).map(|_| rng.gauss_f32(0.0, 1.0)).collect())
        .collect();
    let row_refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    h.case("gossip avg (5x500) native", || {
        std::hint::black_box(dasgd::linalg::mean_of(&row_refs));
    });
    let (dn, cn) = (256usize, 10usize);
    let wn: Vec<f32> = (0..dn * cn).map(|_| rng.gauss_f32(0.0, 0.2)).collect();
    let xn: Vec<f32> = (0..dn).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let mut model_n = LogReg::from_weights(dn, cn, wn.clone());
    h.case("logreg grad step (256x10, b=1) native", || {
        std::hint::black_box(model_n.sgd_step(&[&xn], &[3], 0.1, 1.0));
    });

    // ---- PJRT path ----------------------------------------------------------
    match Engine::load("artifacts") {
        Err(e) => println!("(skipping PJRT cases: {e:#})"),
        Ok(mut engine) => {
            let mut h = Harness::new("PJRT artifact execution (the hot path)");
            let mut y = vec![0.0f32; c];
            y[3] = 1.0;
            let lr = [0.1f32];
            let scale = [1.0f32 / 30.0];
            h.case("logreg_step_synth_b1 (50x10)", || {
                std::hint::black_box(
                    engine
                        .execute_f32("logreg_step_synth_b1", &[&w, &x, &y, &lr, &scale])
                        .unwrap(),
                );
            });
            let mut yn = vec![0.0f32; cn];
            yn[3] = 1.0;
            h.case("logreg_step_notmnist_b1 (256x10)", || {
                std::hint::black_box(
                    engine
                        .execute_f32("logreg_step_notmnist_b1", &[&wn, &xn, &yn, &lr, &scale])
                        .unwrap(),
                );
            });
            let p: Vec<f32> = (0..16 * 500).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let mut wts = vec![0.0f32; 16];
            for v in wts.iter_mut().take(5) {
                *v = 0.2;
            }
            h.case("gossip_avg_synth (16x500)", || {
                std::hint::black_box(engine.execute_f32("gossip_avg_synth", &[&p, &wts]).unwrap());
            });
            let xs: Vec<f32> = (0..256 * 50).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let mut ys = vec![0.0f32; 256 * 10];
            for i in 0..256 {
                ys[i * 10 + (i % 10)] = 1.0;
            }
            h.case("logreg_eval_synth (256 rows)", || {
                std::hint::black_box(
                    engine
                        .execute_f32("logreg_eval_synth", &[&w, &xs, &ys])
                        .unwrap(),
                );
            });
        }
    }

    // ---- transport substrates ----------------------------------------------
    let mut h = Harness::new("transport substrates (ring-10 projection round)");
    let mut transport_rows = bench_transports(&mut h, 500);
    let mut h = Harness::new("wire codec (SocketNet frames)");
    transport_rows.extend(bench_wire(&mut h, 500));
    let mut h = Harness::new("streaming shard data plane");
    transport_rows.extend(bench_stream(&mut h));
    let mut h = Harness::new("observability overhead");
    transport_rows.extend(bench_obs(&mut h));
    let mut h = Harness::new("membership repair (churn events)");
    transport_rows.extend(bench_membership(&mut h));
    let mut h = Harness::new("strategy layer (algorithm zoo dispatch)");
    transport_rows.extend(bench_strategy(&mut h));
    println!("\nscheduler saturation (512 nodes per process)");
    transport_rows.extend(bench_saturation());
    write_transport_baseline(&transport_rows, 500);

    // ---- coordinator machinery ---------------------------------------------
    let mut h = Harness::new("coordinator machinery");
    let mut central = CentralSelector::uniform(30);
    let mut sel_rng = Xoshiro256pp::seeded(9);
    h.case("central selection", || {
        std::hint::black_box(central.next(&mut sel_rng));
    });
    let mut geo = GeometricSelector::uniform(30, 0.05, 11);
    h.case("distributed geometric selection", || {
        std::hint::black_box(geo.next());
    });
    h.case("xoshiro256++ next_u64", || {
        std::hint::black_box(sel_rng.next_u64());
    });
    let g = dasgd::experiments::make_regular(30, 15);
    h.case("closed_neighborhood (deg 15)", || {
        std::hint::black_box(g.closed_neighborhood(7));
    });
}
