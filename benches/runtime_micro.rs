//! Bench: runtime hot-path microbenchmarks — the latency of every PJRT
//! artifact call vs its rust-native equivalent, plus coordinator
//! machinery (selection, RNG, gossip stacking). This is the §Perf
//! measurement harness for L3.
//!
//! Requires `make artifacts`; PJRT cases are skipped (with a note) if
//! the artifact set is missing.

use dasgd::bench::Harness;
use dasgd::coordinator::{CentralSelector, GeometricSelector};
use dasgd::model::LogReg;
use dasgd::runtime::Engine;
use dasgd::util::rng::Xoshiro256pp;

fn main() {
    let mut rng = Xoshiro256pp::seeded(3);

    // ---- native math ------------------------------------------------------
    let mut h = Harness::new("native math (L3 fallback path)");
    let (d, c) = (50usize, 10usize);
    let w: Vec<f32> = (0..d * c).map(|_| rng.gauss_f32(0.0, 0.2)).collect();
    let x: Vec<f32> = (0..d).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let mut model = LogReg::from_weights(d, c, w.clone());
    h.case("logreg grad step (50x10, b=1) native", || {
        std::hint::black_box(model.sgd_step(&[&x], &[3], 0.1, 1.0));
    });
    let rows: Vec<Vec<f32>> = (0..5)
        .map(|_| (0..d * c).map(|_| rng.gauss_f32(0.0, 1.0)).collect())
        .collect();
    let row_refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
    h.case("gossip avg (5x500) native", || {
        std::hint::black_box(dasgd::linalg::mean_of(&row_refs));
    });
    let (dn, cn) = (256usize, 10usize);
    let wn: Vec<f32> = (0..dn * cn).map(|_| rng.gauss_f32(0.0, 0.2)).collect();
    let xn: Vec<f32> = (0..dn).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
    let mut model_n = LogReg::from_weights(dn, cn, wn.clone());
    h.case("logreg grad step (256x10, b=1) native", || {
        std::hint::black_box(model_n.sgd_step(&[&xn], &[3], 0.1, 1.0));
    });

    // ---- PJRT path ----------------------------------------------------------
    match Engine::load("artifacts") {
        Err(e) => println!("(skipping PJRT cases: {e:#})"),
        Ok(mut engine) => {
            let mut h = Harness::new("PJRT artifact execution (the hot path)");
            let mut y = vec![0.0f32; c];
            y[3] = 1.0;
            let lr = [0.1f32];
            let scale = [1.0f32 / 30.0];
            h.case("logreg_step_synth_b1 (50x10)", || {
                std::hint::black_box(
                    engine
                        .execute_f32("logreg_step_synth_b1", &[&w, &x, &y, &lr, &scale])
                        .unwrap(),
                );
            });
            let mut yn = vec![0.0f32; cn];
            yn[3] = 1.0;
            h.case("logreg_step_notmnist_b1 (256x10)", || {
                std::hint::black_box(
                    engine
                        .execute_f32("logreg_step_notmnist_b1", &[&wn, &xn, &yn, &lr, &scale])
                        .unwrap(),
                );
            });
            let p: Vec<f32> = (0..16 * 500).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let mut wts = vec![0.0f32; 16];
            for v in wts.iter_mut().take(5) {
                *v = 0.2;
            }
            h.case("gossip_avg_synth (16x500)", || {
                std::hint::black_box(engine.execute_f32("gossip_avg_synth", &[&p, &wts]).unwrap());
            });
            let xs: Vec<f32> = (0..256 * 50).map(|_| rng.gauss_f32(0.0, 1.0)).collect();
            let mut ys = vec![0.0f32; 256 * 10];
            for i in 0..256 {
                ys[i * 10 + (i % 10)] = 1.0;
            }
            h.case("logreg_eval_synth (256 rows)", || {
                std::hint::black_box(
                    engine
                        .execute_f32("logreg_eval_synth", &[&w, &xs, &ys])
                        .unwrap(),
                );
            });
        }
    }

    // ---- coordinator machinery ---------------------------------------------
    let mut h = Harness::new("coordinator machinery");
    let mut central = CentralSelector::uniform(30);
    let mut sel_rng = Xoshiro256pp::seeded(9);
    h.case("central selection", || {
        std::hint::black_box(central.next(&mut sel_rng));
    });
    let mut geo = GeometricSelector::uniform(30, 0.05, 11);
    h.case("distributed geometric selection", || {
        std::hint::black_box(geo.next());
    });
    h.case("xoshiro256++ next_u64", || {
        std::hint::black_box(sel_rng.next_u64());
    });
    let g = dasgd::experiments::make_regular(30, 15);
    h.case("closed_neighborhood (deg 15)", || {
        std::hint::black_box(g.closed_neighborhood(7));
    });
}
