"""Layer-1 Pallas kernels for multinomial logistic regression.

Two kernels:

* ``logreg_step`` — the Alg. 2 gradient-step hot path. One fused kernel
  computes logits = X @ W, a numerically-stable softmax, the cross-entropy
  gradient G = X^T (p - y) / B, and the in-place SGD update
  W' = W - lr * scale * G, returning the new weights and the mean CE loss.
  Everything (W, the X tile, the (B, C) softmax block) stays resident in
  VMEM; both matmuls are MXU-shaped contractions.

* ``logreg_eval`` — the held-out-metric kernel. A BlockSpec grid tiles the
  evaluation batch along the row axis; each grid step streams one
  (TILE_B, D) tile of X HBM->VMEM, computes per-tile CE-loss sum and
  misclassification count, and accumulates into (1, 1) VMEM accumulators
  (the output block index map pins every grid step to the same block, and
  the Pallas grid is sequential, so read-modify-write accumulation is
  well-defined).

Both kernels run with ``interpret=True`` on this image: the CPU PJRT plugin
cannot execute Mosaic custom-calls, and interpret-mode lowers to plain HLO
that the rust runtime executes. See DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT: Mosaic custom-calls are not executable.


def _step_kernel(x_ref, w_ref, y_ref, lr_ref, scale_ref, w_out_ref, loss_ref):
    """Fused softmax-CE gradient + SGD update, single VMEM block."""
    x = x_ref[...]          # (B, D)
    w = w_ref[...]          # (D, C)
    y = y_ref[...]          # (B, C) one-hot
    lr = lr_ref[0, 0]
    scale = scale_ref[0, 0]

    # MXU contraction 1: logits.
    logits = jnp.dot(x, w, preferred_element_type=jnp.float32)  # (B, C)

    # Numerically-stable log-softmax.
    m = jnp.max(logits, axis=1, keepdims=True)
    z = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    log_p = z - lse                       # (B, C)
    p = jnp.exp(log_p)

    b = x.shape[0]
    # Mean cross-entropy over the (micro)batch.
    loss = -jnp.sum(y * log_p) / b
    loss_ref[0, 0] = loss

    # MXU contraction 2: gradient. G = X^T (p - y) / B.
    g = jnp.dot(x.T, (p - y), preferred_element_type=jnp.float32) / b  # (D, C)

    # `scale` carries the paper's 1/N factor from Eq. (6).
    w_out_ref[...] = w - lr * scale * g


@functools.partial(jax.jit, static_argnames=())
def logreg_step(x, w, y, lr, scale):
    """One Alg. 2 local SGD step on node-local data.

    Args:
      x: (B, D) float32 — feature rows of the sampled data.
      w: (D, C) float32 — the node's local variable beta_i.
      y: (B, C) float32 — one-hot labels.
      lr: (1, 1) float32 — stepsize alpha_k.
      scale: (1, 1) float32 — the 1/N factor of Eq. (6).

    Returns:
      (w_next, loss) with shapes ((D, C), (1, 1)).
    """
    d, c = w.shape
    return pl.pallas_call(
        _step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((d, c), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, w, y, lr, scale)


def _eval_kernel(x_ref, w_ref, y_ref, loss_ref, err_ref):
    """One grid step: CE-loss sum + error count for a (TILE_B, D) tile."""
    t = pl.program_id(0)

    x = x_ref[...]          # (TILE_B, D)
    w = w_ref[...]          # (D, C) — same block every step
    y = y_ref[...]          # (TILE_B, C)

    logits = jnp.dot(x, w, preferred_element_type=jnp.float32)
    m = jnp.max(logits, axis=1, keepdims=True)
    z = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    log_p = z - lse

    tile_loss = -jnp.sum(y * log_p)
    pred = jnp.argmax(logits, axis=1)
    label = jnp.argmax(y, axis=1)
    tile_err = jnp.sum((pred != label).astype(jnp.float32))

    # Sequential-grid accumulation into the pinned (1, 1) output block.
    @pl.when(t == 0)
    def _init():
        loss_ref[0, 0] = tile_loss
        err_ref[0, 0] = tile_err

    @pl.when(t != 0)
    def _acc():
        loss_ref[0, 0] += tile_loss
        err_ref[0, 0] += tile_err


@functools.partial(jax.jit, static_argnames=("tile_b",))
def logreg_eval(x, w, y, tile_b=64):
    """Evaluate W on a held-out batch; returns (loss_sum, err_count).

    The batch axis is tiled with a BlockSpec grid (HBM->VMEM streaming);
    rows must be a multiple of ``tile_b``.
    """
    n, d = x.shape
    _, c = w.shape
    assert n % tile_b == 0, f"eval rows {n} not a multiple of tile {tile_b}"
    grid = (n // tile_b,)
    return pl.pallas_call(
        _eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, d), lambda t: (t, 0)),
            pl.BlockSpec((d, c), lambda t: (0, 0)),
            pl.BlockSpec((tile_b, c), lambda t: (t, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1), lambda t: (0, 0)),
            pl.BlockSpec((1, 1), lambda t: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, w, y)
