"""Layer-1 Pallas kernel for the binary SVM (hinge-loss) SGD step.

Paper §II lists the SVM loss family

    f_i(beta) = (1/K_i) sum_k max(0, 1 - y_k beta^T x_k) + lambda * ||beta||^2

The subgradient on a microbatch is

    g = -(1/B) sum_{k: margin_k < 1} y_k x_k + 2 lambda beta

and the fused kernel performs beta' = beta - lr * scale * g plus the mean
hinge loss, all in one VMEM block (the shapes are tiny: D <= 256).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT: Mosaic custom-calls are not executable.


def _hinge_kernel(x_ref, w_ref, y_ref, lr_ref, scale_ref, lam_ref,
                  w_out_ref, loss_ref):
    x = x_ref[...]          # (B, D)
    w = w_ref[...]          # (1, D)
    y = y_ref[...]          # (1, B), labels in {-1, +1}
    lr = lr_ref[0, 0]
    scale = scale_ref[0, 0]
    lam = lam_ref[0, 0]

    b = x.shape[0]
    margin = y * jnp.dot(w, x.T, preferred_element_type=jnp.float32)  # (1, B)
    active = (margin < 1.0).astype(jnp.float32)                       # (1, B)

    loss = jnp.sum(jnp.maximum(0.0, 1.0 - margin)) / b + lam * jnp.sum(w * w)
    loss_ref[0, 0] = loss

    # g = -(1/B) (active * y) @ X + 2 lam w
    coeff = active * y                                                # (1, B)
    g = -jnp.dot(coeff, x, preferred_element_type=jnp.float32) / b + 2.0 * lam * w
    w_out_ref[...] = w - lr * scale * g


@functools.partial(jax.jit, static_argnames=())
def hinge_step(x, w, y, lr, scale, lam):
    """One SVM subgradient step.

    Args:
      x: (B, D) float32 features.
      w: (1, D) float32 weight row vector.
      y: (1, B) float32 labels in {-1, +1}.
      lr, scale, lam: (1, 1) float32 scalars.

    Returns:
      (w_next, loss) with shapes ((1, D), (1, 1)).
    """
    _, d = w.shape
    return pl.pallas_call(
        _hinge_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, w, y, lr, scale, lam)


def _hinge_eval_kernel(x_ref, w_ref, y_ref, lam_ref, loss_ref, err_ref):
    x = x_ref[...]          # (B, D)
    w = w_ref[...]          # (1, D)
    y = y_ref[...]          # (1, B), labels in {-1, +1}
    lam = lam_ref[0, 0]

    b = x.shape[0]
    pred = jnp.dot(w, x.T, preferred_element_type=jnp.float32)        # (1, B)
    margin = y * pred
    # loss_sum = sum hinge + B * lam * ||w||^2, so loss_sum / B is the
    # regularized mean loss the rust-native eval reports.
    loss_ref[0, 0] = (jnp.sum(jnp.maximum(0.0, 1.0 - margin))
                      + b * lam * jnp.sum(w * w))
    # Sign-misclassification count (pred == 0 predicts the -1 class,
    # matching the native tie-break).
    err_ref[0, 0] = jnp.sum(((pred > 0.0) != (y > 0.0)).astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=())
def hinge_eval(x, w, y, lam):
    """Held-out SVM metrics over a fixed eval batch.

    Args:
      x: (B, D) float32 features.
      w: (1, D) float32 weight row vector.
      y: (1, B) float32 labels in {-1, +1}.
      lam: (1, 1) float32 L2 strength.

    Returns:
      (loss_sum, err_count) with shapes ((1, 1), (1, 1)).
    """
    return pl.pallas_call(
        _hinge_eval_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, w, y, lam)
