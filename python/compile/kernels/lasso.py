"""Layer-1 Pallas kernel for the Lasso (L1-regularized least-squares) step.

Paper §II lists the Lasso loss family

    f_i(beta) = (1/2K_i) sum_k (y_k - beta^T x_k)^2 + lambda * ||beta||_1

The subgradient on a microbatch is

    g = (1/B) X^T (X beta - y) + lambda * sign(beta)

fused with the update beta' = beta - lr * scale * g and the loss value in a
single VMEM block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT: Mosaic custom-calls are not executable.


def _lasso_kernel(x_ref, w_ref, y_ref, lr_ref, scale_ref, lam_ref,
                  w_out_ref, loss_ref):
    x = x_ref[...]          # (B, D)
    w = w_ref[...]          # (1, D)
    y = y_ref[...]          # (1, B)
    lr = lr_ref[0, 0]
    scale = scale_ref[0, 0]
    lam = lam_ref[0, 0]

    b = x.shape[0]
    resid = jnp.dot(w, x.T, preferred_element_type=jnp.float32) - y    # (1, B)
    loss = 0.5 * jnp.sum(resid * resid) / b + lam * jnp.sum(jnp.abs(w))
    loss_ref[0, 0] = loss

    g = jnp.dot(resid, x, preferred_element_type=jnp.float32) / b + lam * jnp.sign(w)
    w_out_ref[...] = w - lr * scale * g


@functools.partial(jax.jit, static_argnames=())
def lasso_step(x, w, y, lr, scale, lam):
    """One Lasso subgradient step.

    Args:
      x: (B, D) float32 features.
      w: (1, D) float32 weight row vector.
      y: (1, B) float32 regression targets.
      lr, scale, lam: (1, 1) float32 scalars.

    Returns:
      (w_next, loss) with shapes ((1, D), (1, 1)).
    """
    _, d = w.shape
    return pl.pallas_call(
        _lasso_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, w, y, lr, scale, lam)


def _lasso_eval_kernel(x_ref, w_ref, y_ref, lam_ref, loss_ref, sq_ref):
    x = x_ref[...]          # (B, D)
    w = w_ref[...]          # (1, D)
    y = y_ref[...]          # (1, B)
    lam = lam_ref[0, 0]

    b = x.shape[0]
    resid = jnp.dot(w, x.T, preferred_element_type=jnp.float32) - y   # (1, B)
    sq = resid * resid
    # loss_sum = 0.5 * sum r^2 + B * lam * ||w||_1, so loss_sum / B is
    # the regularized mean loss the rust-native eval reports; sq_sum / B
    # is the MSE whose sqrt is the RMSE column.
    loss_ref[0, 0] = 0.5 * jnp.sum(sq) + b * lam * jnp.sum(jnp.abs(w))
    sq_ref[0, 0] = jnp.sum(sq)


@functools.partial(jax.jit, static_argnames=())
def lasso_eval(x, w, y, lam):
    """Held-out Lasso metrics over a fixed eval batch.

    Args:
      x: (B, D) float32 features.
      w: (1, D) float32 weight row vector.
      y: (1, B) float32 regression targets.
      lam: (1, 1) float32 L1 strength.

    Returns:
      (loss_sum, sq_sum) with shapes ((1, 1), (1, 1)).
    """
    return pl.pallas_call(
        _lasso_eval_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ),
        interpret=INTERPRET,
    )(x, w, y, lam)
