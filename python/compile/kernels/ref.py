"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: ``python/tests`` sweeps shapes with
hypothesis and asserts the kernels (interpret-mode Pallas) match these
reference implementations to float32 tolerance. They are intentionally
written in the most direct style possible — no fusion, no tiling.
"""

from __future__ import annotations

import jax.numpy as jnp


def logreg_step_ref(x, w, y, lr, scale):
    """Reference for kernels.logreg.logreg_step."""
    b = x.shape[0]
    logits = x @ w
    m = jnp.max(logits, axis=1, keepdims=True)
    z = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    log_p = z - lse
    p = jnp.exp(log_p)
    loss = -jnp.sum(y * log_p) / b
    g = x.T @ (p - y) / b
    w_next = w - lr[0, 0] * scale[0, 0] * g
    return w_next, jnp.full((1, 1), loss, dtype=jnp.float32)


def logreg_eval_ref(x, w, y):
    """Reference for kernels.logreg.logreg_eval: (loss_sum, err_count)."""
    logits = x @ w
    m = jnp.max(logits, axis=1, keepdims=True)
    z = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    log_p = z - lse
    loss_sum = -jnp.sum(y * log_p)
    err = jnp.sum(
        (jnp.argmax(logits, axis=1) != jnp.argmax(y, axis=1)).astype(jnp.float32)
    )
    return (
        jnp.full((1, 1), loss_sum, dtype=jnp.float32),
        jnp.full((1, 1), err, dtype=jnp.float32),
    )


def gossip_avg_ref(p, w):
    """Reference for kernels.gossip.gossip_avg."""
    return w @ p


def hinge_step_ref(x, w, y, lr, scale, lam):
    """Reference for kernels.hinge.hinge_step."""
    b = x.shape[0]
    margin = y * (w @ x.T)
    active = (margin < 1.0).astype(jnp.float32)
    loss = jnp.sum(jnp.maximum(0.0, 1.0 - margin)) / b + lam[0, 0] * jnp.sum(w * w)
    g = -(active * y) @ x / b + 2.0 * lam[0, 0] * w
    w_next = w - lr[0, 0] * scale[0, 0] * g
    return w_next, jnp.full((1, 1), loss, dtype=jnp.float32)


def lasso_step_ref(x, w, y, lr, scale, lam):
    """Reference for kernels.lasso.lasso_step."""
    b = x.shape[0]
    resid = w @ x.T - y
    loss = 0.5 * jnp.sum(resid * resid) / b + lam[0, 0] * jnp.sum(jnp.abs(w))
    g = resid @ x / b + lam[0, 0] * jnp.sign(w)
    w_next = w - lr[0, 0] * scale[0, 0] * g
    return w_next, jnp.full((1, 1), loss, dtype=jnp.float32)
