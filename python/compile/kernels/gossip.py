"""Layer-1 Pallas kernel for the Alg. 2 projection (gossip-average) step.

The projection onto B_m sets every variable in the closed neighborhood
{m} ∪ N_m to the neighborhood mean (paper Eq. (7)). The coordinator stacks
the flattened parameter vectors of the closed neighborhood into P[M_max, K]
(zero rows beyond the actual neighborhood) and supplies a weight vector
w[M_max] with w[i] = 1/(1+|N_m|) on live rows and 0 on padding, so the same
fixed-shape artifact serves every node degree up to M_max - 1.

The kernel is a weighted reduction out[k] = sum_m w[m] * P[m, k], expressed
as a (1, M) x (M, TILE_K) MXU contraction with a BlockSpec grid over the
parameter axis: each grid step streams one (M, TILE_K) tile of P HBM->VMEM
while the tiny weight row stays resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT: Mosaic custom-calls are not executable.


def _gossip_kernel(p_ref, w_ref, o_ref):
    p = p_ref[...]                      # (M, TILE_K)
    w = w_ref[...]                      # (1, M)
    # (1, M) x (M, TILE_K) MXU contraction.
    o_ref[...] = jnp.dot(w, p, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("tile_k",))
def gossip_avg(p, w, tile_k=256):
    """Weighted neighborhood average.

    Args:
      p: (M, K) float32 — stacked flattened neighborhood parameters
         (zero-padded rows beyond the live neighborhood).
      w: (1, M) float32 — averaging weights (0 on padded rows).
      tile_k: grid tile along the parameter axis; K % tile_k == 0.

    Returns:
      (1, K) float32 — the averaged parameter vector.
    """
    m, k = p.shape
    assert k % tile_k == 0, f"param dim {k} not a multiple of tile {tile_k}"
    grid = (k // tile_k,)
    return pl.pallas_call(
        _gossip_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, tile_k), lambda t: (0, t)),
            pl.BlockSpec((1, m), lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_k), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        interpret=INTERPRET,
    )(p, w)
