"""AOT compile path: lower every L2 function to HLO text + a manifest.

Run once by ``make artifacts``; python never runs again after this. The
interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the image's xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 rust crate binds) rejects
(``proto.id() <= INT_MAX``). The HLO text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Every artifact is shape-specialized (PJRT compiles fixed shapes); the
emitted ``manifest.json`` describes inputs/outputs so the rust runtime can
validate call sites at startup.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32


def spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), F32)


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _io(specs):
    return [{"shape": list(s.shape), "dtype": "f32"} for s in specs]


# ---------------------------------------------------------------------------
# Artifact registry. Each entry: name, callable, input specs, output specs.
# Shapes follow DESIGN.md §2: synthetic = (D=50, C=10), notmnist = (D=256,
# C=10); gossip M_max = 16 supports node degree <= 15 (the paper's densest
# topology is 15-regular on 30 nodes); eval batch = 256 rows (tile 64).
# ---------------------------------------------------------------------------


def registry():
    arts = []

    def step_fn(w, x, y, lr, scale):
        return model.logreg_sgd_step(w, x, y, lr, scale)

    for tag, d in (("synth", 50), ("notmnist", 256)):
        c = 10
        for b in (1, 8):
            arts.append(
                dict(
                    name=f"logreg_step_{tag}_b{b}",
                    fn=step_fn,
                    ins=[spec(d, c), spec(b, d), spec(b, c), spec(1, 1), spec(1, 1)],
                    input_names=["w", "x", "y", "lr", "scale"],
                    output_names=["w_next", "loss"],
                    outs=[spec(d, c), spec(1, 1)],
                )
            )
        arts.append(
            dict(
                name=f"logreg_eval_{tag}",
                fn=model.logreg_evaluate,
                ins=[spec(d, c), spec(256, d), spec(256, c)],
                input_names=["w", "x", "y"],
                output_names=["loss_sum", "err_count"],
                outs=[spec(1, 1), spec(1, 1)],
            )
        )
        k = d * c  # flattened parameter length
        # §Perf L1 iteration 2: one grid step per call. The (16, K) stack
        # fits VMEM whole (synth 32 KiB, notmnist 160 KiB « 16 MiB), and
        # interpret-mode grid loops lower to an HLO while-loop whose
        # per-step dynamic-slice overhead dominated the 2-step (synth) /
        # 10-step (notmnist) schedules: 255 µs → ~80 µs per gossip call.
        # On a real TPU the grid would return for K beyond VMEM.
        tile_k = k
        arts.append(
            dict(
                name=f"gossip_avg_{tag}",
                fn=lambda p, wts, tk=tile_k: model.gossip_average(p, wts, tk),
                ins=[spec(16, k), spec(1, 16)],
                input_names=["p", "wts"],
                output_names=["avg"],
                outs=[spec(1, k)],
            )
        )

    # Hinge/lasso eval + (1, 50) gossip artifacts: the (dim)-shaped
    # families run their held-out metrics and Eq. (7) averaging on
    # compiled kernels too (256 eval rows like logreg; gossip stack
    # M_max = 16 over the flat 50-float parameter).
    arts.append(
        dict(
            name="hinge_eval",
            fn=model.hinge_evaluate,
            ins=[spec(1, 50), spec(256, 50), spec(1, 256), spec(1, 1)],
            input_names=["w", "x", "y", "lam"],
            output_names=["loss_sum", "err_count"],
            outs=[spec(1, 1), spec(1, 1)],
        )
    )
    arts.append(
        dict(
            name="lasso_eval",
            fn=model.lasso_evaluate,
            ins=[spec(1, 50), spec(256, 50), spec(1, 256), spec(1, 1)],
            input_names=["w", "x", "y", "lam"],
            output_names=["loss_sum", "sq_sum"],
            outs=[spec(1, 1), spec(1, 1)],
        )
    )
    arts.append(
        dict(
            name="gossip_avg_dim50",
            fn=lambda p, wts: model.gossip_average(p, wts, 50),
            ins=[spec(16, 50), spec(1, 16)],
            input_names=["p", "wts"],
            output_names=["avg"],
            outs=[spec(1, 50)],
        )
    )
    for b in (1, 8):
        arts.append(
            dict(
                name=f"hinge_step_b{b}",
                fn=model.hinge_sgd_step,
                ins=[spec(1, 50), spec(b, 50), spec(1, b), spec(1, 1), spec(1, 1), spec(1, 1)],
                input_names=["w", "x", "y", "lr", "scale", "lam"],
                output_names=["w_next", "loss"],
                outs=[spec(1, 50), spec(1, 1)],
            )
        )
        arts.append(
            dict(
                name=f"lasso_step_b{b}",
                fn=model.lasso_sgd_step,
                ins=[spec(1, 50), spec(b, 50), spec(1, b), spec(1, 1), spec(1, 1), spec(1, 1)],
                input_names=["w", "x", "y", "lr", "scale", "lam"],
                output_names=["w_next", "loss"],
                outs=[spec(1, 50), spec(1, 1)],
            )
        )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="path of the sentinel artifact (its directory "
                         "receives all artifacts + manifest.json)")
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": []}
    for art in registry():
        lowered = jax.jit(art["fn"]).lower(*art["ins"])
        text = to_hlo_text(lowered)
        fname = f"{art['name']}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": art["name"],
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "inputs": [
                    dict(name=n, **io)
                    for n, io in zip(art["input_names"], _io(art["ins"]))
                ],
                "outputs": [
                    dict(name=n, **io)
                    for n, io in zip(art["output_names"], _io(art["outs"]))
                ],
            }
        )
        print(f"  {art['name']}: {len(text)} chars")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)

    # Sentinel for the Makefile dependency: concatenated names + hashes.
    with open(args.out, "w") as f:
        for a in manifest["artifacts"]:
            f.write(f"{a['name']} {a['sha256']}\n")
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
