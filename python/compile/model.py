"""Layer-2 JAX model: the paper's data-fitting objectives, calling L1 kernels.

These are the functions that get AOT-lowered to HLO text by ``aot.py`` and
executed from the rust coordinator. Each wraps one or more Pallas kernels so
the kernel lowers into the same HLO module; no other compute happens on the
request path.

The paper's §V experiments are multinomial logistic regression (10 classes;
50 features synthetic, 256 features notMNIST); §II additionally motivates
SVM and Lasso loss families, which we expose the same way.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import gossip, hinge, lasso, logreg


def logreg_sgd_step(w, x, y, lr, scale):
    """One Alg. 2 gradient step (Eq. 6): returns (w_next, loss).

    ``scale`` carries the 1/N factor; the coordinator folds any extra
    importance weighting (non-uniform node selection) into it.
    """
    w_next, loss = logreg.logreg_step(x, w, y, lr, scale)
    return w_next, loss


def logreg_evaluate(w, x, y):
    """Held-out metrics: returns (loss_sum, err_count) over the eval batch.

    The caller divides by the row count to get mean CE loss and the
    prediction error of Figs. 3/4/6.
    """
    # §Perf L1 iteration 3: tile_b = 128 (2 grid steps) instead of 64 (4).
    # VMEM per tile: 128×256×4 = 128 KiB « 16 MiB; halving the interpret
    # while-loop trip count cut the eval artifact 690 µs → ~400 µs. Kept
    # at 2 steps (not 1) so the accumulate-across-grid path stays
    # exercised end-to-end.
    loss_sum, err_count = logreg.logreg_eval(x, w, y, tile_b=128)
    return loss_sum, err_count


def hinge_sgd_step(w, x, y, lr, scale, lam):
    """One SVM subgradient step: returns (w_next, loss)."""
    return hinge.hinge_step(x, w, y, lr, scale, lam)


def lasso_sgd_step(w, x, y, lr, scale, lam):
    """One Lasso subgradient step: returns (w_next, loss)."""
    return lasso.lasso_step(x, w, y, lr, scale, lam)


def hinge_evaluate(w, x, y, lam):
    """Held-out SVM metrics: returns (loss_sum, err_count).

    ``loss_sum`` folds the L2 term (``B * lam * ||w||^2``) so the caller
    recovers the regularized mean loss by dividing by the row count.
    """
    return hinge.hinge_eval(x, w, y, lam)


def lasso_evaluate(w, x, y, lam):
    """Held-out Lasso metrics: returns (loss_sum, sq_sum).

    ``sq_sum / B`` is the MSE; its sqrt is the RMSE column the rust
    side reports.
    """
    return lasso.lasso_eval(x, w, y, lam)


def gossip_average(p, wts, tile_k):
    """Projection step (Eq. 7): weighted closed-neighborhood average.

    ``p`` is (M_max, K) zero-padded stacked parameters, ``wts`` is (1, M_max)
    with 1/(1+|N_m|) on live rows. Returns the (1, K) averaged vector which
    the coordinator broadcasts back to the closed neighborhood.
    """
    return gossip.gossip_avg(p, wts, tile_k=tile_k)


# ---------------------------------------------------------------------------
# Pure-jax helpers used by the python-side tests (not lowered to artifacts).
# ---------------------------------------------------------------------------


def predict(w, x):
    """Class predictions (argmax of logits)."""
    return jnp.argmax(x @ w, axis=1)


def ce_loss(w, x, y):
    """Mean cross-entropy (pure jax; used to sanity-check training)."""
    logits = x @ w
    log_p = jax.nn.log_softmax(logits, axis=1)
    return -jnp.mean(jnp.sum(y * log_p, axis=1))
