"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis sweeps shapes; every Pallas kernel (interpret mode) must match
its pure-jnp oracle in ``kernels.ref`` to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gossip, hinge, lasso, logreg, ref

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=15, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


def onehot(labels, c):
    return np.eye(c, dtype=np.float32)[labels]


# ---------------------------------------------------------------------------
# logreg_step
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 16),
    d=st.integers(2, 96),
    c=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_logreg_step_matches_ref(b, d, c, seed):
    r = rng(seed)
    x = r.normal(size=(b, d)).astype(np.float32)
    w = r.normal(size=(d, c)).astype(np.float32) * 0.1
    y = onehot(r.integers(0, c, size=b), c)
    lr = np.full((1, 1), 0.05, np.float32)
    scale = np.full((1, 1), 1.0 / 30.0, np.float32)

    w_k, loss_k = logreg.logreg_step(x, w, y, lr, scale)
    w_r, loss_r = ref.logreg_step_ref(x, w, y, lr, scale)
    np.testing.assert_allclose(w_k, w_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(loss_k, loss_r, rtol=1e-5, atol=1e-6)


def test_logreg_step_reduces_loss():
    """A few steps of the kernel on separable data must reduce the loss."""
    r = rng(0)
    d, c, b = 20, 4, 8
    w = np.zeros((d, c), np.float32)
    means = r.normal(size=(c, d)).astype(np.float32) * 2.0
    lr = np.full((1, 1), 0.5, np.float32)
    scale = np.full((1, 1), 1.0, np.float32)
    losses = []
    for k in range(60):
        labels = r.integers(0, c, size=b)
        x = means[labels] + r.normal(size=(b, d)).astype(np.float32) * 0.3
        y = onehot(labels, c)
        w, loss = logreg.logreg_step(x.astype(np.float32), w, y, lr, scale)
        w = np.asarray(w)
        losses.append(float(loss[0, 0]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.5


def test_logreg_step_zero_lr_is_identity():
    r = rng(3)
    x = r.normal(size=(1, 50)).astype(np.float32)
    w = r.normal(size=(50, 10)).astype(np.float32)
    y = onehot(r.integers(0, 10, size=1), 10)
    zero = np.zeros((1, 1), np.float32)
    one = np.ones((1, 1), np.float32)
    w_k, _ = logreg.logreg_step(x, w, y, zero, one)
    np.testing.assert_array_equal(np.asarray(w_k), w)


# ---------------------------------------------------------------------------
# logreg_eval (grid-tiled)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    tiles=st.integers(1, 4),
    d=st.integers(2, 64),
    c=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_logreg_eval_matches_ref(tiles, d, c, seed):
    tile_b = 16
    n = tiles * tile_b
    r = rng(seed)
    x = r.normal(size=(n, d)).astype(np.float32)
    w = r.normal(size=(d, c)).astype(np.float32) * 0.2
    y = onehot(r.integers(0, c, size=n), c)

    loss_k, err_k = logreg.logreg_eval(x, w, y, tile_b=tile_b)
    loss_r, err_r = ref.logreg_eval_ref(x, w, y)
    np.testing.assert_allclose(loss_k, loss_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(err_k, err_r, rtol=0, atol=0)


def test_logreg_eval_perfect_classifier_zero_errors():
    c, d = 5, 5
    n = 64
    r = rng(1)
    labels = r.integers(0, c, size=n)
    x = onehot(labels, c) * 10.0
    w = np.eye(d, c, dtype=np.float32)
    y = onehot(labels, c)
    _, err = logreg.logreg_eval(x, w, y, tile_b=64)
    assert float(err[0, 0]) == 0.0


def test_logreg_eval_rejects_ragged_batch():
    with pytest.raises(AssertionError):
        logreg.logreg_eval(
            np.zeros((65, 4), np.float32),
            np.zeros((4, 3), np.float32),
            np.zeros((65, 3), np.float32),
            tile_b=64,
        )


# ---------------------------------------------------------------------------
# gossip_avg (grid-tiled)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 16),
    ktiles=st.integers(1, 5),
    live=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_gossip_avg_matches_ref(m, ktiles, live, seed):
    tile_k = 32
    k = ktiles * tile_k
    live = min(live, m)
    r = rng(seed)
    p = r.normal(size=(m, k)).astype(np.float32)
    p[live:] = 0.0
    wts = np.zeros((1, m), np.float32)
    wts[0, :live] = 1.0 / live

    out_k = gossip.gossip_avg(p, wts, tile_k=tile_k)
    out_r = ref.gossip_avg_ref(p, wts)
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-6)


def test_gossip_avg_uniform_rows_is_fixed_point():
    """Averaging identical parameters returns them unchanged (consensus)."""
    k = 256
    row = np.linspace(-1, 1, k, dtype=np.float32)
    p = np.tile(row, (16, 1))
    wts = np.full((1, 16), 1.0 / 16.0, np.float32)
    out = gossip.gossip_avg(p, wts, tile_k=64)
    np.testing.assert_allclose(np.asarray(out)[0], row, rtol=1e-5, atol=1e-6)


def test_gossip_avg_padding_rows_ignored():
    """Zero-weighted padding rows must not influence the average."""
    k = 64
    r = rng(7)
    p = r.normal(size=(16, k)).astype(np.float32)
    wts = np.zeros((1, 16), np.float32)
    wts[0, :3] = 1.0 / 3.0
    full = np.asarray(gossip.gossip_avg(p, wts, tile_k=32))
    p2 = p.copy()
    p2[3:] = 1e6  # garbage in padding rows
    padded = np.asarray(gossip.gossip_avg(p2, wts, tile_k=32))
    np.testing.assert_allclose(full, padded, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# hinge_step
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 16),
    d=st.integers(2, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_hinge_step_matches_ref(b, d, seed):
    r = rng(seed)
    x = r.normal(size=(b, d)).astype(np.float32)
    w = r.normal(size=(1, d)).astype(np.float32) * 0.1
    y = (r.integers(0, 2, size=(1, b)) * 2 - 1).astype(np.float32)
    lr = np.full((1, 1), 0.05, np.float32)
    scale = np.full((1, 1), 1.0, np.float32)
    lam = np.full((1, 1), 0.01, np.float32)

    w_k, loss_k = hinge.hinge_step(x, w, y, lr, scale, lam)
    w_r, loss_r = ref.hinge_step_ref(x, w, y, lr, scale, lam)
    np.testing.assert_allclose(w_k, w_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(loss_k, loss_r, rtol=1e-5, atol=1e-6)


def test_hinge_inactive_margin_only_regularizer():
    """If every margin > 1 the data term vanishes: pure L2 shrinkage."""
    d = 8
    w = np.full((1, d), 0.5, np.float32)
    x = w.copy() * 100.0  # margin = y * w.x >> 1 for y=+1
    y = np.ones((1, 1), np.float32)
    lr = np.full((1, 1), 0.1, np.float32)
    scale = np.ones((1, 1), np.float32)
    lam = np.full((1, 1), 0.05, np.float32)
    w_k, _ = hinge.hinge_step(x, w, y, lr, scale, lam)
    expect = w - 0.1 * 1.0 * (2 * 0.05 * w)
    np.testing.assert_allclose(np.asarray(w_k), expect, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# lasso_step
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    b=st.integers(1, 16),
    d=st.integers(2, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_lasso_step_matches_ref(b, d, seed):
    r = rng(seed)
    x = r.normal(size=(b, d)).astype(np.float32)
    w = r.normal(size=(1, d)).astype(np.float32)
    y = r.normal(size=(1, b)).astype(np.float32)
    lr = np.full((1, 1), 0.02, np.float32)
    scale = np.full((1, 1), 1.0, np.float32)
    lam = np.full((1, 1), 0.1, np.float32)

    w_k, loss_k = lasso.lasso_step(x, w, y, lr, scale, lam)
    w_r, loss_r = ref.lasso_step_ref(x, w, y, lr, scale, lam)
    np.testing.assert_allclose(w_k, w_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(loss_k, loss_r, rtol=1e-4, atol=1e-5)


def test_lasso_exact_fit_loss_is_regularizer_only():
    r = rng(11)
    d, b = 6, 4
    w = r.normal(size=(1, d)).astype(np.float32)
    x = r.normal(size=(b, d)).astype(np.float32)
    y = (w @ x.T).astype(np.float32)  # exact fit: residual = 0
    lr = np.zeros((1, 1), np.float32)
    scale = np.ones((1, 1), np.float32)
    lam = np.full((1, 1), 0.5, np.float32)
    _, loss = lasso.lasso_step(x, w, y, lr, scale, lam)
    np.testing.assert_allclose(
        float(loss[0, 0]), 0.5 * float(np.abs(w).sum()), rtol=1e-5
    )
