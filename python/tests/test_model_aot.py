"""L2 model shape/semantics tests + AOT lowering round-trip checks."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_registry_shapes_consistent():
    """Every registry entry's fn must lower with its declared input specs."""
    arts = aot.registry()
    names = [a["name"] for a in arts]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for art in arts:
        assert len(art["ins"]) == len(art["input_names"])
        assert len(art["outs"]) == len(art["output_names"])


def test_lower_and_hlo_text_roundtrip():
    """A representative artifact lowers to parseable HLO text."""
    arts = {a["name"]: a for a in aot.registry()}
    art = arts["logreg_step_synth_b1"]
    lowered = jax.jit(art["fn"]).lower(*art["ins"])
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ROOT" in text
    # return_tuple=True: the entry computation returns a tuple.
    assert "tuple(" in text or "(f32[" in text


def test_artifact_outputs_match_declared_shapes():
    """Execute each step fn with zeros; outputs must match declared specs."""
    for art in aot.registry():
        ins = [np.zeros(s.shape, np.float32) for s in art["ins"]]
        outs = jax.jit(art["fn"])(*ins)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        assert len(outs) == len(art["outs"]), art["name"]
        for got, want in zip(outs, art["outs"]):
            assert got.shape == want.shape, (
                f"{art['name']}: got {got.shape}, want {want.shape}"
            )
            assert got.dtype == jnp.float32


def test_main_writes_manifest(tmp_path=None):
    """End-to-end aot.main() into a temp dir produces a valid manifest."""
    tmp = tempfile.mkdtemp()
    sentinel = os.path.join(tmp, "model.hlo.txt")
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out", sentinel]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(os.path.join(tmp, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text"
    assert len(manifest["artifacts"]) >= 12
    for a in manifest["artifacts"]:
        path = os.path.join(tmp, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            assert f.read(9) == "HloModule"
        assert a["inputs"] and a["outputs"]


def test_model_predict_and_ce_loss():
    r = np.random.default_rng(0)
    d, c, n = 10, 4, 32
    w = r.normal(size=(d, c)).astype(np.float32)
    x = r.normal(size=(n, d)).astype(np.float32)
    labels = np.argmax(x @ w, axis=1)
    y = np.eye(c, dtype=np.float32)[labels]
    pred = model.predict(w, x)
    np.testing.assert_array_equal(np.asarray(pred), labels)
    # CE of the true argmax labels must beat CE of shuffled labels.
    ce_true = float(model.ce_loss(w, x, y))
    y_shuf = np.eye(c, dtype=np.float32)[(labels + 1) % c]
    ce_shuf = float(model.ce_loss(w, x, y_shuf))
    assert ce_true < ce_shuf


def test_gossip_average_tile_paths_agree():
    """model.gossip_average must be tile-size invariant."""
    r = np.random.default_rng(5)
    p = r.normal(size=(16, 512)).astype(np.float32)
    wts = np.zeros((1, 16), np.float32)
    wts[0, :5] = 0.2
    a = np.asarray(model.gossip_average(p, wts, 512))
    b = np.asarray(model.gossip_average(p, wts, 128))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
