#!/usr/bin/env bash
# Loopback deployment smoke legs for CI (.github/workflows/ci.yml).
#
# Each leg drives `dasgd launch` — real worker processes plus the
# monitor over loopback TCP — and relies on launch's own exit code:
# it exits nonzero whenever the wall-clock cap beats the update
# horizon (LaunchReport.reached_horizon), so a stalled deployment
# fails the leg without any timeout heuristics.
#
# Usage: tools/ci_smoke.sh basic|heterogeneous|observability|churn|compare
set -euo pipefail

leg="${1:?usage: tools/ci_smoke.sh basic|heterogeneous|observability|churn|compare}"

run() { cargo run --release -- "$@"; }

case "$leg" in
  basic)
    # Two real worker processes + the monitor over loopback TCP: the
    # deployment path must reach its update horizon and shut down
    # cleanly on a stock runner. Shards ship over the wire.
    run launch --workers 2 --nodes 8 --horizon 2000
    ;;

  heterogeneous)
    # Label-skew Dirichlet split + mixed hinge/lasso objectives:
    # workers receive their (distinct, non-IID) shards from the
    # monitor and must still reach the horizon.
    run launch --workers 2 --nodes 8 --horizon 2000 \
      --plan mixed --dirichlet-alpha 0.1
    ;;

  observability)
    # An instrumented launch must serve a live Prometheus endpoint
    # mid-run and leave behind schema-valid metrics/trace JSONL with
    # nonzero cluster-wide staleness mass (docs/observability.md).
    # The long horizon keeps the deployment alive while we scrape.
    # The endpoint answers with an empty page until the monitor's
    # first aggregation round completes, so retry until the scraped
    # body actually carries the staleness metric — a bare 200 is
    # not "up" yet. Trace events fire inside the workers; launch
    # forwards --trace-jsonl as per-rank trace.rankN.jsonl files
    # while the monitor's own round events land in trace.jsonl.
    run launch --workers 2 --nodes 8 --horizon 20000 \
      --metrics-jsonl metrics.jsonl --trace-jsonl trace.jsonl \
      --log-level debug --metrics-addr 127.0.0.1:9900 &
    LAUNCH_PID=$!
    for i in $(seq 1 60); do
      if curl -sf http://127.0.0.1:9900/metrics -o scrape.txt \
         && grep -q 'dasgd_staleness_ticks' scrape.txt; then
        break
      fi
      sleep 1
    done
    grep -q 'dasgd_staleness_ticks' scrape.txt
    grep -q 'dasgd_steals_total' scrape.txt
    wait "$LAUNCH_PID"
    python3 tools/check_metrics.py metrics.jsonl --require-staleness
    python3 tools/check_metrics.py trace.jsonl --kind trace
    python3 tools/check_metrics.py trace.rank0.jsonl --kind trace
    python3 tools/check_metrics.py trace.rank1.jsonl --kind trace
    ;;

  churn)
    # Membership smoke: three workers; the monitor SIGKILLs rank 2
    # once the aggregate passes 30% of the horizon and admits a
    # `worker --join` replacement past 60% (the rank must first have
    # been vacated by the heartbeat eviction — the same path a real
    # crash takes). Reaching the horizon certifies the handoff: every
    # re-streamed shard is checksum-verified block by block and the
    # joiner refuses the stream on any mismatch. The metrics export
    # must carry the eviction, the join, and the topology repairs.
    run launch --workers 3 --nodes 9 --degree 2 --horizon 60000 \
      --secs 240 --chaos-kill 2@0.3 --chaos-join 0.6 \
      --metrics-jsonl metrics-churn.jsonl --log-level info
    python3 tools/check_metrics.py metrics-churn.jsonl \
      --require-counter evictions --require-counter joins \
      --require-counter repairs
    ;;

  compare)
    # Algorithm-zoo smoke: all four update strategies race the same
    # small SimNet schedule (docs/algorithms.md) and dump one CSV.
    # The leg checks the dump has exactly one block per strategy on
    # the shared append-only run schema and that every strategy's
    # final consensus residual stays under a generous tolerance —
    # a zoo member that diverges or stalls fails CI here.
    run compare --strategies dasgd,dcasgd,delay-agnostic,rfast \
      --nodes 10 --degree 4 --horizon 30 --eval-every 10 \
      --csv compare.csv
    python3 - <<'EOF'
import collections
import csv
import sys

rows = list(csv.DictReader(open("compare.csv")))
if not rows:
    sys.exit("compare.csv has no records")
blocks = collections.defaultdict(list)
for r in rows:
    blocks[r["strategy"]].append(r)
want = {"dasgd", "dcasgd", "delay-agnostic", "rfast"}
if set(blocks) != want:
    sys.exit(f"strategy blocks {sorted(blocks)} != {sorted(want)}")
for name, rs in sorted(blocks.items()):
    if len(rs) < 2:
        sys.exit(f"{name}: only {len(rs)} snapshots")
    final = float(rs[-1]["consensus"])
    if not final < 25.0:
        sys.exit(f"{name}: final consensus residual {final} above tolerance 25.0")
    print(f"{name}: {len(rs)} snapshots, final consensus {final:.3f}")
EOF
    ;;

  *)
    echo "unknown smoke leg: $leg" >&2
    exit 2
    ;;
esac
