#!/usr/bin/env python3
"""Validate dasgd observability JSONL exports (stdlib only).

Checks every line of a --metrics-jsonl or --trace-jsonl file against
the schemas documented in docs/observability.md and exits nonzero with
a pointed message on the first violation. Used by the CI loopback
smoke and the nightly launch legs.

Usage:
    python3 tools/check_metrics.py metrics.jsonl [--require-staleness]
    python3 tools/check_metrics.py trace.jsonl --kind trace
"""

import argparse
import json
import sys

COUNTERS = [
    "steals",
    "b8_collapses",
    "credit_stalls",
    "conflicts",
    "reconnects",
    "joins",
    "evictions",
    "repairs",
]
GAUGES = ["staging_high_water_bytes", "chunk_high_water_bytes"]
HISTS = [
    "fire_to_apply_us",
    "message_delay_us",
    "staleness_ticks",
    "timer_lag_us",
    "flush_bytes",
]
HIST_BUCKETS = 64
TRACE_KEYS = ["kind", "seq", "t_us", "component", "event", "node", "detail"]


def fail(path, lineno, msg):
    sys.exit(f"{path}:{lineno}: {msg}")


_warned_extra = set()


def check_catalog(path, lineno, section, block, names):
    """The wire format and JSONL schema are append-only: a file from a
    build with *more* metrics than this checker knows is valid (extras
    are noted once, not failed); one missing a catalog metric is not.
    """
    missing = sorted(set(names) - set(block))
    if missing:
        fail(path, lineno, f"{section} missing catalog keys {missing}")
    for key in sorted(set(block) - set(names)):
        if (section, key) not in _warned_extra:
            _warned_extra.add((section, key))
            print(
                f"{path}:{lineno}: note: {section} key {key!r} is not in this "
                "checker's catalog (tolerated: the format is append-only)",
                file=sys.stderr,
            )


def check_uint(path, lineno, name, v):
    if not isinstance(v, int) or isinstance(v, bool) or v < 0:
        fail(path, lineno, f"{name} must be a non-negative integer, got {v!r}")


def check_hist(path, lineno, name, h):
    if not isinstance(h, dict):
        fail(path, lineno, f"hist {name} must be an object")
    for key in ("count", "sum"):
        check_uint(path, lineno, f"hists.{name}.{key}", h.get(key))
    for key in ("p50", "p99"):
        if not isinstance(h.get(key), (int, float)):
            fail(path, lineno, f"hists.{name}.{key} must be a number")
    buckets = h.get("buckets")
    if not isinstance(buckets, list):
        fail(path, lineno, f"hists.{name}.buckets must be a list")
    mass = 0
    for pair in buckets:
        if (
            not isinstance(pair, list)
            or len(pair) != 2
            or not all(isinstance(x, int) and x >= 0 for x in pair)
        ):
            fail(path, lineno, f"hists.{name}.buckets entries must be [index, count]")
        index, count = pair
        if index >= HIST_BUCKETS:
            fail(path, lineno, f"hists.{name} bucket index {index} >= {HIST_BUCKETS}")
        if count == 0:
            fail(path, lineno, f"hists.{name} sparse buckets must omit zero counts")
        mass += count
    if mass != h["count"]:
        fail(path, lineno, f"hists.{name} bucket mass {mass} != count {h['count']}")


def check_metrics_line(path, lineno, obj):
    if obj.get("kind") != "metrics":
        fail(path, lineno, f"kind must be 'metrics', got {obj.get('kind')!r}")
    if not isinstance(obj.get("scope"), str) or not obj["scope"]:
        fail(path, lineno, "scope must be a non-empty string")
    if not isinstance(obj.get("t_secs"), (int, float)) or obj["t_secs"] < 0:
        fail(path, lineno, "t_secs must be a non-negative number")
    check_uint(path, lineno, "k", obj.get("k"))
    for section, names in (("counters", COUNTERS), ("gauges", GAUGES)):
        block = obj.get(section)
        if not isinstance(block, dict):
            fail(path, lineno, f"{section} must be an object")
        check_catalog(path, lineno, section, block, names)
        for name, v in block.items():
            check_uint(path, lineno, f"{section}.{name}", v)
    hists = obj.get("hists")
    if not isinstance(hists, dict):
        fail(path, lineno, "hists must be an object")
    check_catalog(path, lineno, "hists", hists, HISTS)
    # Only catalog histograms are shape-checked — an extra hist from a
    # newer build may legitimately extend the schema.
    for name in HISTS:
        check_hist(path, lineno, name, hists[name])


def check_trace_line(path, lineno, obj, prev_seq):
    if obj.get("kind") != "trace":
        fail(path, lineno, f"kind must be 'trace', got {obj.get('kind')!r}")
    if sorted(obj) != sorted(TRACE_KEYS):
        fail(path, lineno, f"trace keys {sorted(obj)} != {sorted(TRACE_KEYS)}")
    for key in ("seq", "t_us", "node", "detail"):
        check_uint(path, lineno, key, obj[key])
    for key in ("component", "event"):
        if not isinstance(obj[key], str) or not obj[key]:
            fail(path, lineno, f"{key} must be a non-empty string")
    if prev_seq is not None and obj["seq"] <= prev_seq:
        fail(path, lineno, f"seq {obj['seq']} not after previous {prev_seq}")
    return obj["seq"]


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="JSONL file to validate")
    ap.add_argument(
        "--kind",
        choices=["metrics", "trace"],
        default="metrics",
        help="which schema to check (default: metrics)",
    )
    ap.add_argument(
        "--require-staleness",
        action="store_true",
        help="fail unless the final metrics line has staleness_ticks count > 0",
    )
    ap.add_argument(
        "--require-counter",
        action="append",
        default=[],
        metavar="NAME",
        help="fail unless the final metrics line has counters[NAME] > 0 "
        "(repeatable; used by the churn smoke for evictions/joins)",
    )
    args = ap.parse_args()
    if (args.require_staleness or args.require_counter) and args.kind != "metrics":
        ap.error("--require-staleness/--require-counter only apply to --kind metrics")

    lines = 0
    prev_seq = None
    last = None
    try:
        with open(args.path, encoding="utf-8") as f:
            for lineno, raw in enumerate(f, start=1):
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    obj = json.loads(raw)
                except json.JSONDecodeError as e:
                    fail(args.path, lineno, f"invalid JSON: {e}")
                if args.kind == "metrics":
                    check_metrics_line(args.path, lineno, obj)
                else:
                    prev_seq = check_trace_line(args.path, lineno, obj, prev_seq)
                lines += 1
                last = obj
    except OSError as e:
        sys.exit(f"{args.path}: {e}")

    if lines == 0:
        sys.exit(f"{args.path}: no JSONL lines found")
    if args.require_staleness:
        count = last["hists"]["staleness_ticks"]["count"]
        if count == 0:
            sys.exit(f"{args.path}: final line has an empty staleness_ticks histogram")
    for name in args.require_counter:
        value = last["counters"].get(name)
        if value is None:
            sys.exit(f"{args.path}: final line has no counter {name!r}")
        if value == 0:
            sys.exit(f"{args.path}: final line has counters[{name!r}] == 0")
    print(f"{args.path}: {lines} {args.kind} line(s) OK")


if __name__ == "__main__":
    main()
