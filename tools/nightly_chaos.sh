#!/usr/bin/env bash
# Nightly chaos leg (.github/workflows/nightly.yml): a 1000-node,
# 4-worker deployment under scheduled external churn.
#
# The monitor opens a join listener (--join-addr) and prints its bound
# address; this script then SIGKILLs one incumbent worker every
# KILL_EVERY seconds and spawns a `dasgd worker --join` replacement
# REJOIN_AFTER seconds later — from the outside, the way an operator
# (or an orchestrator) would, exercising the public join path rather
# than the in-monitor --chaos-* hooks the CI smoke uses.
#
# Failure modes, all fatal:
#   - stall: `launch` exits nonzero when the wall-clock cap beats the
#     update horizon;
#   - missing churn: the metrics export must show nonzero evictions,
#     joins, and repairs;
#   - divergence: the final consensus residual in the CSV must be
#     under TOL.
set -euo pipefail

KILL_EVERY="${KILL_EVERY:-15}"
REJOIN_AFTER="${REJOIN_AFTER:-8}"
TOL="${TOL:-25.0}"
BIN="${BIN:-target/release/dasgd}"
# The update strategy the deployment runs (docs/algorithms.md). The
# strategy-zoo churn variant sets STRATEGY=rfast: gradient trackers
# gossip as v8 aux blobs across every collect/apply frame, joiners
# inherit the strategy code from their JoinGrant, and mid-churn
# neighborhoods mix tracker-carrying members with fresh ones whose
# blobs are still empty — the cross-strategy blob interop under the
# same kill/rejoin schedule as the baseline leg.
STRATEGY="${STRATEGY:-dasgd}"

cargo build --release

"$BIN" launch --workers 4 --nodes 1000 --degree 4 --samples 50 \
  --rate 50 --horizon 2000000 --secs 300 \
  --strategy "$STRATEGY" \
  --join-addr 127.0.0.1:0 \
  --metrics-jsonl metrics-chaos.jsonl --csv chaos.csv --log-level info \
  > launch.out 2> launch.err &
LAUNCH_PID=$!

# The monitor prints its join listener address once the deployment is
# streaming; replacements dial it.
ADDR=""
for _ in $(seq 1 120); do
  ADDR=$(sed -n 's/^dasgd-launch join-addr=//p' launch.out | head -n 1)
  [ -n "$ADDR" ] && break
  if ! kill -0 "$LAUNCH_PID" 2>/dev/null; then
    echo "launch died before printing its join address" >&2
    cat launch.out launch.err >&2
    exit 1
  fi
  sleep 1
done
if [ -z "$ADDR" ]; then
  echo "no join-addr line after 120s" >&2
  cat launch.out launch.err >&2
  exit 1
fi
echo "chaos: monitor join listener at $ADDR"

# Kill/rejoin cycles while the run lives. Incumbents carry a
# `worker --rank N` command line; once one is gone its replacement
# runs as `worker --join`, so later cycles fall through to killing a
# joined replacement — both shapes must survive the same path.
RANK=1
while kill -0 "$LAUNCH_PID" 2>/dev/null; do
  sleep "$KILL_EVERY" &
  wait $! || true
  kill -0 "$LAUNCH_PID" 2>/dev/null || break
  if pkill -KILL -f "worker --rank $RANK"; then
    echo "chaos: SIGKILLed incumbent worker rank $RANK"
  elif pkill -KILL --oldest -f "worker --join"; then
    echo "chaos: SIGKILLed a joined replacement worker"
  else
    echo "chaos: no worker matched rank $RANK (already churned)"
  fi
  RANK=$((RANK % 3 + 1))
  sleep "$REJOIN_AFTER"
  kill -0 "$LAUNCH_PID" 2>/dev/null || break
  "$BIN" worker --join "$ADDR" --log-level warn > /dev/null 2>&1 &
  echo "chaos: spawned a --join replacement"
done

# Nonzero exactly when the deployment stalled before the horizon.
if ! wait "$LAUNCH_PID"; then
  echo "chaos run stalled before the horizon" >&2
  tail -n 40 launch.err >&2
  exit 1
fi

python3 tools/check_metrics.py metrics-chaos.jsonl \
  --require-counter evictions --require-counter joins \
  --require-counter repairs

# The run converged despite the churn: final consensus residual under
# tolerance.
TOL="$TOL" python3 - <<'EOF'
import csv
import os
import sys

rows = list(csv.DictReader(open("chaos.csv")))
if not rows:
    sys.exit("chaos.csv has no records")
final = float(rows[-1]["consensus"])
tol = float(os.environ["TOL"])
print(f"final consensus residual {final:.3f} (tolerance {tol})")
if not final < tol:
    sys.exit(f"consensus residual {final:.3f} above tolerance {tol}")
EOF
